//! Scalar statistics helpers: standard-normal pdf/cdf (via an `erf`
//! implementation — not in `std`) used by Expected Improvement, plus
//! small summary-statistics utilities shared by the tuners and benches.

/// Error function, max absolute error ≈ 1.2e-7 (Abramowitz & Stegun
/// 7.1.26 with the Horner form popularized by Numerical Recipes).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal probability density.
#[inline]
pub fn normal_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Standard normal cumulative distribution.
#[inline]
pub fn normal_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

/// Sample mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Median (copies and sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// p-th percentile (0 ≤ p ≤ 100), linear interpolation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_known_values() {
        // Reference values from tables.
        let cases = [
            (0.0, 0.0),
            (0.5, 0.5204998778),
            (1.0, 0.8427007929),
            (2.0, 0.9953222650),
            (-1.0, -0.8427007929),
        ];
        for (x, want) in cases {
            assert!((erf(x) - want).abs() < 2e-7, "erf({x}) = {} want {want}", erf(x));
        }
    }

    #[test]
    fn cdf_symmetry_and_tails() {
        // A&S 7.1.26 is a ~1e-7-accurate approximation, not exact at 0.
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-7);
        for z in [0.3, 1.1, 2.5] {
            assert!((normal_cdf(z) + normal_cdf(-z) - 1.0).abs() < 1e-7);
        }
        assert!(normal_cdf(8.0) > 0.999999);
        assert!(normal_cdf(-8.0) < 1e-6);
    }

    #[test]
    fn pdf_integrates_to_cdf() {
        // Trapezoid ∫ pdf ≈ cdf difference.
        let (a, b) = (-1.5f64, 0.7f64);
        let steps = 10_000;
        let h = (b - a) / steps as f64;
        let mut acc = 0.0;
        for i in 0..steps {
            let x0 = a + i as f64 * h;
            acc += 0.5 * h * (normal_pdf(x0) + normal_pdf(x0 + h));
        }
        assert!((acc - (normal_cdf(b) - normal_cdf(a))).abs() < 1e-6);
    }

    #[test]
    fn summary_stats() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert_eq!(median(&[7.0]), 7.0);
    }
}
