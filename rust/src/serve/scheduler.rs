//! The session scheduler: one reusable driver for tuning sessions
//! ([`drive_session`], extracted from `campaign::runner`) plus the
//! daemon's fair-share [`Scheduler`] that time-slices many concurrent
//! jobs onto it.
//!
//! ## The extracted driver
//!
//! PR 4 made sessions pausable ask/tell state machines with atomic
//! checkpoints precisely so a scheduler could time-slice them. The
//! assembly around a session — build the problem, derive the seed
//! streams, collect TLA source data, attach the checkpoint — used to
//! live inside the campaign runner; [`drive_session`] hoists it into a
//! shared primitive consumed by both the campaign (whole-session or
//! `--max-trials`-limited visits) and the serving daemon (batch-granular
//! slices via [`SliceLimits::max_batches`]). Seed derivation is
//! unchanged down to the salt constants, so campaign results are
//! byte-identical to the pre-extraction code.
//!
//! ## The serving scheduler
//!
//! [`Scheduler`] owns the daemon's job table. Jobs run as round-robin
//! time slices at **trial-batch granularity**: a worker claims the
//! longest-waiting ready job (skipping tenants at their concurrent-slice
//! cap — the fair-share policy), resumes its session from the checkpoint
//! for [`ServeConfig::slice_batches`] batches, and requeues it. Because a
//! sliced session asks its tuner the identical question sequence an
//! uninterrupted run would (no batch is ever split), a job's recorded
//! trials are a pure function of its state file — never of worker count
//! or interleaving.
//!
//! Completed jobs commit like campaign cells: shard first, job state
//! second, crowd fold third, session-checkpoint removal last. The crowd
//! database is always rebuilt by re-reading done-job shards in job-id
//! order, so `crowd.json` is byte-identical across worker counts and
//! across kill/restart cycles — pinned by `tests/serve_scheduler.rs`.

use super::job::{JobManifest, JobState, JobStatus, StateDirs};
use crate::campaign::TunerKind;
use crate::data::ProblemSpec;
use crate::db::HistoryDb;
use crate::json::Json;
use crate::objective::{
    Constants, History, Objective, ParallelEvaluator, SessionOutcome, StopReason, Trial,
    TuningSession, TuningTask,
};
use crate::tuners::SourceSample;
use std::collections::{BTreeMap, VecDeque};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};

/// Salt separating the tuner's proposal RNG from the objective's solver
/// streams within a session (moved verbatim from `campaign::runner`).
const TUNER_SEED_SALT: u64 = 0x7454_4e52_u64;
/// Salt separating TLA source collection from everything else.
const SOURCE_SEED_SALT: u64 = 0x5059_4c0a_u64;

/// Everything that determines a session's recorded trials: the problem,
/// the tuner, the budget, the derived seed, and the objective constants.
/// Both the campaign runner and the serving scheduler build one of these
/// and hand it to [`drive_session`].
#[derive(Clone, Debug)]
pub struct SessionSpec {
    /// The problem to tune.
    pub problem: ProblemSpec,
    /// The tuner to run on it.
    pub tuner: TunerKind,
    /// Evaluation budget (reference included).
    pub budget: usize,
    /// The session's base seed (a campaign cell seed, or
    /// [`JobManifest::session_seed`]); objective, tuner, and source
    /// streams are salted off it exactly as the campaign always did.
    pub session_seed: u64,
    /// Objective constants (repeats, timing mode, penalty/allowance).
    pub constants: Constants,
    /// Threads for within-session batch evaluation (1 = serial).
    pub eval_threads: usize,
    /// TLA only: source samples collected on the down-scaled sibling.
    pub source_samples: usize,
}

impl SessionSpec {
    /// The session spec of a job manifest.
    pub fn from_manifest(m: &JobManifest) -> SessionSpec {
        SessionSpec {
            problem: m.problem(),
            tuner: m.tuner,
            budget: m.budget,
            session_seed: m.session_seed(),
            constants: Constants {
                num_repeats: m.repeats,
                timing: m.timing,
                ..Constants::default()
            },
            eval_threads: m.eval_threads,
            source_samples: m.source_samples,
        }
    }
}

/// How much of the session one [`drive_session`] invocation may run.
#[derive(Clone, Copy, Debug, Default)]
pub struct SliceLimits {
    /// Pause after this many new evaluations (the campaign's
    /// `--max-trials` countdown; proposal batches are split exactly).
    pub max_new_evals: Option<usize>,
    /// Pause after this many evaluated batches (the daemon's time-slice
    /// unit; batches are never split).
    pub max_batches: Option<usize>,
}

impl SliceLimits {
    /// No limits: run the session to a genuine stop.
    pub fn none() -> SliceLimits {
        SliceLimits::default()
    }
}

/// Assemble and run (or resume) one tuning session: build the problem,
/// derive the seed streams, collect TLA source data when the tuner needs
/// it, attach the checkpoint at `ckpt_path` (resuming from it if it
/// exists), inject `warm` trials into the tuner, and drive the ask/tell
/// loop until a stop rule or a [`SliceLimits`] quota fires.
///
/// `observer` (when given) sees every newly recorded trial in order —
/// the daemon's per-batch progress stream hook.
pub fn drive_session(
    spec: &SessionSpec,
    ckpt_path: &Path,
    limits: SliceLimits,
    warm: &[Trial],
    observer: Option<&mut dyn FnMut(&Trial)>,
) -> Result<SessionOutcome, String> {
    let family = crate::families::get(&spec.problem.family).ok_or_else(|| {
        format!(
            "unknown problem family {:?}; expected {}",
            spec.problem.family,
            crate::families::known_names()
        )
    })?;
    let problem = spec.problem.build()?;
    // The spec's family wins over whatever Constants carried (SessionSpec
    // builders default it); everything downstream — reference solve,
    // per-repeat evaluation, fingerprint — keys off these constants.
    let constants = Constants { family, ..spec.constants.clone() };
    let source = if spec.tuner.needs_source() {
        collect_session_source(spec, &constants)?
    } else {
        Vec::new()
    };
    let task = TuningTask { problem, space: family.space(), constants: constants.clone() };
    let mut obj = Objective::new(task, spec.session_seed);
    if spec.eval_threads > 1 {
        obj.set_evaluator(Box::new(ParallelEvaluator::new(spec.eval_threads)));
    }
    let mut tuner = spec.tuner.make(constants.num_pilots, source, family);
    let mut session = TuningSession::new(
        &mut obj,
        tuner.as_mut(),
        spec.budget,
        spec.session_seed ^ TUNER_SEED_SALT,
    )
    .checkpoint_to(ckpt_path);
    if !warm.is_empty() {
        session = session.warm_start(warm);
    }
    if let Some(q) = limits.max_new_evals {
        session = session.pause_after(q);
    }
    if let Some(b) = limits.max_batches {
        session = session.pause_after_batches(b);
    }
    if let Some(obs) = observer {
        session = session.on_trial(move |t| obs(t));
    }
    session.run()
}

/// Pre-collect TLA source samples on a down-scaled sibling of the
/// problem: same generator family, m/4 rows (floored at n + 50), shifted
/// data seed — the paper's §5.3.1 source protocol, fully determined by
/// the spec (moved verbatim from `campaign::runner`).
fn collect_session_source(
    spec: &SessionSpec,
    constants: &Constants,
) -> Result<Vec<SourceSample>, String> {
    let p = &spec.problem;
    let src_m = (p.m / 4).max(p.n + 50).min(p.m);
    let src_problem = crate::data::build_problem(&p.dataset, src_m, p.n, p.data_seed + 400)?;
    Ok(crate::cli::figures::collect_source(
        src_problem,
        constants.clone(),
        spec.source_samples,
        spec.session_seed ^ SOURCE_SEED_SALT,
    ))
}

/// Scheduler tunables.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Max concurrent slices per tenant (the fair-share cap).
    pub tenant_cap: usize,
    /// Trial batches per scheduling slice (1 = finest-grained rotation).
    pub slice_batches: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig { tenant_cap: 2, slice_batches: 1 }
    }
}

/// Mutable scheduler state behind the lock.
struct SchedInner {
    /// All known jobs, keyed by id (sorted ⇒ deterministic fold order).
    jobs: BTreeMap<String, JobState>,
    /// Round-robin ready queue of non-terminal job ids.
    queue: VecDeque<String>,
    /// Concurrent slices in flight per tenant.
    tenant_active: BTreeMap<String, usize>,
    /// Total slices in flight.
    in_flight: usize,
    /// Next job sequence number.
    next_seq: u64,
    /// In-memory copy of the crowd database (mirrors `crowd.json`).
    crowd: HistoryDb,
}

/// The daemon's job scheduler: accepts jobs, time-slices their sessions
/// across worker threads with per-tenant fair-share caps, and folds
/// completed jobs into the shared crowd [`HistoryDb`].
pub struct Scheduler {
    dirs: StateDirs,
    config: ServeConfig,
    inner: Mutex<SchedInner>,
    cv: Condvar,
    draining: AtomicBool,
}

fn lock_inner<'s>(m: &'s Mutex<SchedInner>) -> MutexGuard<'s, SchedInner> {
    // Scheduler state is updated in small consistent steps; recover from
    // poisoning like the kernel pool does (fatal-for-a-daemon otherwise).
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl Scheduler {
    /// Open (or create) a scheduler over a state directory, restoring
    /// every persisted job: terminal jobs keep their status, all others
    /// are requeued — their sessions resume mid-run from their
    /// checkpoints. The crowd database is rebuilt from done-job shards.
    pub fn open(dirs: StateDirs, config: ServeConfig) -> Result<Scheduler, String> {
        dirs.init()?;
        let jobs_vec = dirs.load_jobs()?;
        let mut jobs = BTreeMap::new();
        let mut queue = VecDeque::new();
        let mut next_seq = 1u64;
        for j in jobs_vec {
            if let Some(seq) =
                j.id.strip_prefix("job-").and_then(|s| s.parse::<u64>().ok())
            {
                next_seq = next_seq.max(seq + 1);
            }
            if !j.status.is_terminal() {
                queue.push_back(j.id.clone());
            } else {
                // A kill between job-state write and checkpoint removal
                // leaves an orphan session file; sweep it.
                std::fs::remove_file(dirs.session_path(&j.id)).ok();
            }
            jobs.insert(j.id.clone(), j);
        }
        let crowd = fold_crowd(&dirs, &jobs)?;
        crowd.save(&dirs.crowd_path()).map_err(|e| e.to_string())?;
        Ok(Scheduler {
            dirs,
            config,
            inner: Mutex::new(SchedInner {
                jobs,
                queue,
                tenant_active: BTreeMap::new(),
                in_flight: 0,
                next_seq,
                crowd,
            }),
            cv: Condvar::new(),
            draining: AtomicBool::new(false),
        })
    }

    /// The scheduler's state directory.
    pub fn dirs(&self) -> &StateDirs {
        &self.dirs
    }

    /// Accept a job: snapshot its warm-start trials from the current
    /// crowd database (determinism anchor — the snapshot is persisted in
    /// the job state, so a restarted daemon re-runs the job with the
    /// identical warm set), persist, and enqueue. Refused while draining.
    pub fn submit(&self, manifest: JobManifest) -> Result<JobState, String> {
        if self.draining.load(Ordering::Acquire) {
            return Err("daemon is draining; job refused".into());
        }
        let mut inner = lock_inner(&self.inner);
        let id = format!("job-{:06}", inner.next_seq);
        inner.next_seq += 1;
        let warm_trials = if manifest.warm {
            let mut trials = Vec::new();
            for rec in inner.crowd.tasks_named(&manifest.problem_id()) {
                trials.extend(rec.to_history().trials().iter().cloned());
            }
            trials
        } else {
            Vec::new()
        };
        let state = JobState {
            id: id.clone(),
            manifest,
            status: JobStatus::Queued,
            error: None,
            warm_trials,
        };
        state.save(&self.dirs)?;
        inner.jobs.insert(id.clone(), state.clone());
        inner.queue.push_back(id);
        drop(inner);
        self.cv.notify_all();
        Ok(state)
    }

    /// Snapshot of one job's state.
    pub fn job(&self, id: &str) -> Option<JobState> {
        lock_inner(&self.inner).jobs.get(id).cloned()
    }

    /// Snapshot of every job, in id (= submission) order.
    pub fn jobs(&self) -> Vec<JobState> {
        lock_inner(&self.inner).jobs.values().cloned().collect()
    }

    /// Snapshot of the crowd database.
    pub fn crowd(&self) -> HistoryDb {
        lock_inner(&self.inner).crowd.clone()
    }

    /// Begin a graceful drain: no new jobs are accepted, workers finish
    /// their current slice (each slice ends on a fresh checkpoint) and
    /// exit. Safe to call from a signal-adjacent context.
    pub fn drain(&self) {
        self.draining.store(true, Ordering::Release);
        self.cv.notify_all();
    }

    /// Is a drain in progress?
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Acquire)
    }

    /// Run `workers` scheduler threads until every known job is terminal
    /// (then return) or a drain is requested. The calling thread hosts
    /// one of the workers.
    pub fn run_until_idle(&self, workers: usize) {
        self.run_workers(workers, true);
    }

    /// Run `workers` scheduler threads until [`Scheduler::drain`] is
    /// called — the daemon's serving loop. The calling thread hosts one
    /// of the workers.
    pub fn run_until_drained(&self, workers: usize) {
        self.run_workers(workers, false);
    }

    fn run_workers(&self, workers: usize, until_idle: bool) {
        let workers = workers.max(1);
        std::thread::scope(|s| {
            for _ in 1..workers {
                s.spawn(move || self.worker_loop(until_idle));
            }
            self.worker_loop(until_idle);
        });
    }

    fn worker_loop(&self, until_idle: bool) {
        while let Some(id) = self.claim(until_idle) {
            let sliced = self.run_slice(&id);
            self.retire_slice(&id, sliced);
        }
    }

    /// Claim the longest-waiting ready job whose tenant is under the
    /// fair-share cap; block until one exists. Returns `None` once the
    /// loop should exit (drain requested, or — in until-idle mode —
    /// nothing left to run).
    fn claim(&self, until_idle: bool) -> Option<String> {
        let mut inner = lock_inner(&self.inner);
        loop {
            if self.draining.load(Ordering::Acquire) {
                return None;
            }
            let cap = self.config.tenant_cap.max(1);
            let pos = inner.queue.iter().position(|id| {
                let tenant = &inner.jobs[id].manifest.tenant;
                inner.tenant_active.get(tenant).copied().unwrap_or(0) < cap
            });
            if let Some(p) = pos {
                let id = inner.queue.remove(p).expect("position came from the queue");
                let tenant = inner.jobs[&id].manifest.tenant.clone();
                *inner.tenant_active.entry(tenant).or_insert(0) += 1;
                inner.in_flight += 1;
                if let Some(j) = inner.jobs.get_mut(&id) {
                    j.status = JobStatus::Running;
                }
                return Some(id);
            }
            if until_idle && inner.queue.is_empty() && inner.in_flight == 0 {
                // Wake siblings so they observe idleness and exit too.
                self.cv.notify_all();
                return None;
            }
            inner = self.cv.wait(inner).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Run one time slice of a job's session (outside the lock).
    fn run_slice(&self, id: &str) -> Result<SessionOutcome, String> {
        let (spec, warm) = {
            let inner = lock_inner(&self.inner);
            let j = inner.jobs.get(id).ok_or("job vanished from the table")?;
            (SessionSpec::from_manifest(&j.manifest), j.warm_trials.clone())
        };
        drive_session(
            &spec,
            &self.dirs.session_path(id),
            SliceLimits { max_new_evals: None, max_batches: Some(self.config.slice_batches) },
            &warm,
            None,
        )
    }

    /// Fold the slice outcome back into the job table: requeue on pause,
    /// commit on completion, record failures.
    fn retire_slice(&self, id: &str, sliced: Result<SessionOutcome, String>) {
        let mut inner = lock_inner(&self.inner);
        if let Some(j) = inner.jobs.get(id) {
            let tenant = j.manifest.tenant.clone();
            if let Some(a) = inner.tenant_active.get_mut(&tenant) {
                *a = a.saturating_sub(1);
            }
        }
        inner.in_flight = inner.in_flight.saturating_sub(1);
        let result = match sliced {
            Ok(out) if out.stop == StopReason::Paused => {
                inner.queue.push_back(id.to_string());
                Ok(())
            }
            Ok(out) => self.commit_job(&mut inner, id, &out.history),
            Err(e) => Err(e),
        };
        if let Err(e) = result {
            if let Some(j) = inner.jobs.get_mut(id) {
                j.status = JobStatus::Failed;
                j.error = Some(e);
                let _ = j.save(&self.dirs);
            }
        }
        drop(inner);
        self.cv.notify_all();
    }

    /// Commit a completed job, mirroring the campaign's kill-safe order:
    /// shard first, job state second, crowd fold third, session
    /// checkpoint removal last. A kill between any two steps re-runs the
    /// remaining steps idempotently on restart (the resumed session
    /// replays to the identical history from its checkpoint).
    fn commit_job(
        &self,
        inner: &mut SchedInner,
        id: &str,
        history: &History,
    ) -> Result<(), String> {
        let manifest = inner.jobs.get(id).ok_or("job vanished from the table")?.manifest.clone();
        let mut shard = HistoryDb::new();
        shard.record(&manifest.problem_id(), manifest.m, manifest.n, history);
        shard.save(&self.dirs.shard_path(id)).map_err(|e| e.to_string())?;
        if let Some(j) = inner.jobs.get_mut(id) {
            j.status = JobStatus::Done;
            j.error = None;
            j.save(&self.dirs)?;
        }
        let crowd = fold_crowd(&self.dirs, &inner.jobs)?;
        crowd.save(&self.dirs.crowd_path()).map_err(|e| e.to_string())?;
        inner.crowd = crowd;
        std::fs::remove_file(self.dirs.session_path(id)).ok();
        Ok(())
    }

    /// A job's recorded trials so far, as JSON values: from its shard
    /// once done, else from its live session checkpoint — the per-batch
    /// progress stream behind `GET /v1/jobs/<id>/trials`.
    pub fn trials_json(&self, id: &str) -> Result<Vec<Json>, String> {
        let Some(job) = self.job(id) else {
            return Err(format!("unknown job {id:?}"));
        };
        if job.status == JobStatus::Done {
            let shard = HistoryDb::load(&self.dirs.shard_path(id))?;
            let rec = shard
                .all_tasks()
                .into_iter()
                .next()
                .ok_or_else(|| format!("shard for {id} is empty"))?;
            return Ok(rec.to_history().trials().iter().map(Trial::to_json).collect());
        }
        let path = self.dirs.session_path(id);
        if !path.exists() {
            return Ok(Vec::new());
        }
        let text = std::fs::read_to_string(&path).map_err(|e| e.to_string())?;
        let doc = Json::parse(&text)?;
        Ok(doc
            .get("trials")
            .and_then(|x| x.as_arr())
            .map(|a| a.to_vec())
            .unwrap_or_default())
    }
}

/// Rebuild the crowd database from done-job shards, folded in job-id
/// (= submission) order — deterministic regardless of which worker
/// finished which job when.
fn fold_crowd(dirs: &StateDirs, jobs: &BTreeMap<String, JobState>) -> Result<HistoryDb, String> {
    let mut db = HistoryDb::new();
    for (id, j) in jobs {
        if j.status == JobStatus::Done {
            db.merge_from(&HistoryDb::load(&dirs.shard_path(id))?);
        }
    }
    Ok(db)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::TimingMode;

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("ranntune_sched_{tag}_{}", std::process::id()))
    }

    fn modeled_job(tuner: TunerKind, budget: usize, seed: u64) -> JobManifest {
        let mut m = JobManifest::new("GA", 260, 12, tuner);
        m.budget = budget;
        m.seed = seed;
        m.repeats = 1;
        m.timing = TimingMode::Modeled;
        m
    }

    #[test]
    fn drive_session_runs_and_slices_resumably() {
        let dir = tmp("drive");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let spec = SessionSpec::from_manifest(&modeled_job(TunerKind::Lhsmdu, 5, 3));
        let ckpt = dir.join("sess.json");

        // Full run in one go.
        let full = drive_session(&spec, &ckpt, SliceLimits::none(), &[], None).unwrap();
        assert_eq!(full.history.len(), 5);
        std::fs::remove_file(&ckpt).unwrap();

        // Batch-sliced run, one batch per call, with a progress observer.
        let mut seen = 0usize;
        let sliced = loop {
            let mut obs = |_: &Trial| seen += 1;
            let out = drive_session(
                &spec,
                &ckpt,
                SliceLimits { max_new_evals: None, max_batches: Some(1) },
                &[],
                Some(&mut obs),
            )
            .unwrap();
            if out.stop.is_finished() {
                break out;
            }
        };
        assert_eq!(sliced.history.len(), 5);
        assert_eq!(seen, 5, "observer must see every new trial exactly once");
        for (a, b) in full.history.trials().iter().zip(sliced.history.trials()) {
            assert_eq!(a.config, b.config);
            assert_eq!(a.value.to_bits(), b.value.to_bits());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn scheduler_completes_jobs_and_folds_crowd() {
        let dir = tmp("basic");
        let _ = std::fs::remove_dir_all(&dir);
        let sched =
            Scheduler::open(StateDirs::new(&dir), ServeConfig::default()).unwrap();
        let a = sched.submit(modeled_job(TunerKind::Lhsmdu, 4, 1)).unwrap();
        let b = sched.submit(modeled_job(TunerKind::Tpe, 5, 2)).unwrap();
        assert_eq!(a.id, "job-000001");
        assert_eq!(b.id, "job-000002");
        sched.run_until_idle(2);
        for j in sched.jobs() {
            assert_eq!(j.status, JobStatus::Done, "{:?}", j.error);
        }
        let crowd = HistoryDb::load(&sched.dirs().crowd_path()).unwrap();
        // Both jobs tune the same problem fingerprint ⇒ one crowd task
        // holding 4 + 5 trials.
        assert_eq!(crowd.len(), 1);
        assert_eq!(crowd.source_samples("GA-260x12-s1", 260, 12).len(), 9);
        assert_eq!(sched.trials_json(&a.id).unwrap().len(), 4);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn warm_snapshot_is_taken_at_submission() {
        let dir = tmp("warm");
        let _ = std::fs::remove_dir_all(&dir);
        let sched =
            Scheduler::open(StateDirs::new(&dir), ServeConfig::default()).unwrap();
        // Job 1 populates the crowd db.
        sched.submit(modeled_job(TunerKind::Lhsmdu, 4, 1)).unwrap();
        sched.run_until_idle(1);
        // Job 2 with warm=true snapshots job 1's 4 trials.
        let mut m = modeled_job(TunerKind::Tpe, 5, 2);
        m.warm = true;
        let s2 = sched.submit(m).unwrap();
        assert_eq!(s2.warm_trials.len(), 4);
        // The snapshot is durable: a reopened scheduler sees it.
        sched.drain();
        drop(sched);
        let re = Scheduler::open(StateDirs::new(&dir), ServeConfig::default()).unwrap();
        let j2 = re.job(&s2.id).unwrap();
        assert_eq!(j2.status, JobStatus::Queued);
        assert_eq!(j2.warm_trials.len(), 4);
        re.run_until_idle(1);
        assert_eq!(re.job(&s2.id).unwrap().status, JobStatus::Done);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn failed_jobs_record_their_error() {
        let dir = tmp("fail");
        let _ = std::fs::remove_dir_all(&dir);
        let sched =
            Scheduler::open(StateDirs::new(&dir), ServeConfig::default()).unwrap();
        let mut bad = modeled_job(TunerKind::Lhsmdu, 4, 1);
        bad.dataset = "NotADataset".into();
        let s = sched.submit(bad).unwrap();
        sched.run_until_idle(1);
        let j = sched.job(&s.id).unwrap();
        assert_eq!(j.status, JobStatus::Failed);
        assert!(j.error.is_some());
        // Failed jobs contribute nothing to the crowd.
        assert!(sched.crowd().is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn drain_refuses_new_jobs() {
        let dir = tmp("drain");
        let _ = std::fs::remove_dir_all(&dir);
        let sched =
            Scheduler::open(StateDirs::new(&dir), ServeConfig::default()).unwrap();
        sched.drain();
        assert!(sched.is_draining());
        let err = sched.submit(modeled_job(TunerKind::Lhsmdu, 3, 1)).unwrap_err();
        assert!(err.contains("draining"));
        // Workers exit promptly under drain.
        sched.run_until_drained(2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tenant_cap_never_deadlocks_mixed_tenants() {
        let dir = tmp("tenants");
        let _ = std::fs::remove_dir_all(&dir);
        let sched = Scheduler::open(
            StateDirs::new(&dir),
            ServeConfig { tenant_cap: 1, slice_batches: 1 },
        )
        .unwrap();
        for (i, tenant) in ["a", "a", "b", "b"].iter().enumerate() {
            let mut m = modeled_job(TunerKind::Lhsmdu, 3, i as u64);
            m.tenant = (*tenant).into();
            sched.submit(m).unwrap();
        }
        sched.run_until_idle(4);
        assert!(sched.jobs().iter().all(|j| j.status == JobStatus::Done));
        std::fs::remove_dir_all(&dir).ok();
    }
}
