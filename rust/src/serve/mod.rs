//! Tuning-as-a-service: the `ranntune serve` daemon and its client.
//!
//! The paper frames surrogate autotuning as something a *facility* runs
//! continuously, not a one-shot script: GPTune's history database is
//! explicitly a crowd resource that later users' tunings draw from
//! (§2.3, §5.3). This module is that deployment shape for the crate's
//! pipeline — a long-running daemon that accepts tuning jobs over
//! HTTP/JSON, time-slices their sessions across a small worker pool
//! with per-tenant fair-share caps, and folds every completed job into
//! one shared crowd [`crate::db::HistoryDb`] keyed by problem
//! fingerprint, so later submissions warm-start from earlier tenants'
//! evaluations and TLA transfer-learns across them.
//!
//! Everything is pure std, like the rest of the crate: the HTTP layer
//! ([`http`]) is a deliberately tiny one-request-per-connection subset,
//! job manifests ([`job`]) are versioned hand-rolled JSON with
//! `BTreeMap`-sorted keys, and the scheduler ([`scheduler`]) reuses the
//! pausable [`crate::objective::TuningSession`] checkpoints as its
//! time-slice mechanism.
//!
//! ## Crash and drain story
//!
//! Every slice ends on an atomically-written session checkpoint and
//! every state transition on an atomically-written job file, so
//! `kill -9` at any instant loses at most the current in-flight batch:
//! a restarted daemon requeues every non-terminal job and resumes each
//! session from its checkpoint, asking the tuner the identical question
//! sequence (batch slicing never splits a proposal batch). `SIGTERM`
//! (or `POST /v1/drain`) is the graceful version — stop accepting
//! jobs, let workers finish their current slice, checkpoint, exit.
//!
//! ## Routes
//!
//! | method & path              | meaning                                |
//! |----------------------------|----------------------------------------|
//! | `GET /v1/healthz`          | liveness + drain flag                  |
//! | `POST /v1/jobs`            | submit a job manifest → job state      |
//! | `GET /v1/jobs`             | list all jobs                          |
//! | `GET /v1/jobs/ID`          | one job's state                        |
//! | `GET /v1/jobs/ID/trials`   | recorded trials so far (`?since=K`)    |
//! | `GET /v1/db`               | the crowd history database             |
//! | `POST /v1/drain`           | graceful drain (also `/v1/shutdown`)   |

pub mod http;
pub mod job;
pub mod scheduler;

pub use job::{JobManifest, JobState, JobStatus, StateDirs};
pub use scheduler::{drive_session, Scheduler, ServeConfig, SessionSpec, SliceLimits};

use crate::json::Json;
use http::Request;
use std::io::ErrorKind;
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

/// Daemon options, filled from `ranntune serve` flags.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// State directory (jobs, sessions, shards, crowd db, addr file).
    pub state: PathBuf,
    /// TCP port to listen on (0 = OS-assigned; the bound address is
    /// printed and written to `<state>/addr` either way).
    pub port: u16,
    /// Scheduler worker threads.
    pub workers: usize,
    /// Fair-share and slicing tunables.
    pub config: ServeConfig,
}

/// Set by the SIGTERM/SIGINT handler; polled by the accept loop.
static TERM_FLAG: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
fn install_term_handler() {
    extern "C" fn on_term(_sig: i32) {
        // Only async-signal-safe work here: set the flag, nothing else.
        TERM_FLAG.store(true, Ordering::Release);
    }
    extern "C" {
        // std already links libc; bind `signal` directly rather than
        // growing a dependency for one syscall.
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> isize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGTERM, on_term);
        signal(SIGINT, on_term);
    }
}

#[cfg(not(unix))]
fn install_term_handler() {}

/// Run the daemon: open the scheduler over the state directory (resuming
/// any jobs a previous process left non-terminal), bind the listener,
/// write `<state>/addr`, and serve until drained.
pub fn run(opts: &ServeOpts) -> Result<(), String> {
    install_term_handler();
    let sched = Scheduler::open(StateDirs::new(&opts.state), opts.config.clone())?;
    let listener = TcpListener::bind(("127.0.0.1", opts.port))
        .map_err(|e| format!("bind 127.0.0.1:{}: {e}", opts.port))?;
    listener.set_nonblocking(true).map_err(|e| e.to_string())?;
    let addr = listener.local_addr().map_err(|e| e.to_string())?;
    crate::fsio::write_atomic(&sched.dirs().addr_path(), &addr.to_string())
        .map_err(|e| e.to_string())?;
    println!("ranntune serve: listening on {addr} (state {})", opts.state.display());

    std::thread::scope(|s| {
        let sref = &sched;
        let workers = s.spawn(move || sref.run_until_drained(opts.workers));
        loop {
            if TERM_FLAG.load(Ordering::Acquire) {
                sched.drain();
            }
            if sched.is_draining() {
                break;
            }
            match listener.accept() {
                Ok((mut conn, _)) => handle_conn(&sched, &mut conn),
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(50));
                }
                Err(_) => std::thread::sleep(Duration::from_millis(50)),
            }
        }
        workers.join().ok();
    });
    std::fs::remove_file(sched.dirs().addr_path()).ok();
    println!("ranntune serve: drained, exiting");
    Ok(())
}

fn handle_conn(sched: &Scheduler, conn: &mut TcpStream) {
    let req = match http::read_request(conn) {
        Ok(r) => r,
        Err(_) => return, // malformed request: just drop the connection
    };
    let (status, body) = route(sched, &req);
    let _ = http::respond(conn, status, &body);
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![("error", Json::Str(msg.into()))])
}

/// Dispatch one request against the scheduler.
fn route(sched: &Scheduler, req: &Request) -> (u16, Json) {
    let path = req.path.trim_matches('/').to_string();
    let segs: Vec<&str> = path.split('/').collect();
    match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["v1", "healthz"]) => (
            200,
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("draining", Json::Bool(sched.is_draining())),
            ]),
        ),
        ("POST", ["v1", "jobs"]) => {
            let submitted = req
                .json()
                .and_then(|doc| JobManifest::from_json(&doc))
                .and_then(|m| sched.submit(m));
            match submitted {
                Ok(state) => (202, state.to_json()),
                Err(e) => (400, err_json(&e)),
            }
        }
        ("GET", ["v1", "jobs"]) => (
            200,
            Json::obj(vec![(
                "jobs",
                Json::Arr(sched.jobs().iter().map(JobState::to_json).collect()),
            )]),
        ),
        ("GET", ["v1", "jobs", id]) => match sched.job(id) {
            Some(state) => (200, state.to_json()),
            None => (404, err_json(&format!("unknown job {id:?}"))),
        },
        ("GET", ["v1", "jobs", id, "trials"]) => {
            let since = req
                .query
                .get("since")
                .and_then(|s| s.parse::<usize>().ok())
                .unwrap_or(0);
            match sched.trials_json(id) {
                Ok(trials) => {
                    let total = trials.len();
                    let tail: Vec<Json> = trials.into_iter().skip(since).collect();
                    (
                        200,
                        Json::obj(vec![
                            ("total", Json::Num(total as f64)),
                            ("trials", Json::Arr(tail)),
                        ]),
                    )
                }
                Err(e) => (404, err_json(&e)),
            }
        }
        ("GET", ["v1", "db"]) => (200, sched.crowd().to_json()),
        ("POST", ["v1", "drain"]) | ("POST", ["v1", "shutdown"]) => {
            sched.drain();
            (202, Json::obj(vec![("draining", Json::Bool(true))]))
        }
        _ => (404, err_json(&format!("no route {} {}", req.method, req.path))),
    }
}

// ---- client ----

/// What `ranntune client` should do against a running daemon.
#[derive(Clone, Debug)]
pub enum ClientAction {
    /// `GET /v1/healthz`, print the response.
    Health,
    /// Submit a manifest (inline JSON text or a path to a JSON file);
    /// prints the accepted job state (its `id` field names the job).
    Submit(String),
    /// Print one job's state (or all jobs when the id is empty).
    Status(String),
    /// Poll a job until it reaches a terminal status; print the final
    /// state. Exits with an error if the job failed or the timeout hit.
    Wait(String),
    /// Print a job's recorded trials so far.
    Trials(String),
    /// Fetch the crowd database; print it, or write it to the path.
    Db(Option<PathBuf>),
    /// Ask the daemon to drain gracefully.
    Drain,
}

/// Client options, filled from `ranntune client` flags.
#[derive(Clone, Debug)]
pub struct ClientOpts {
    /// Daemon address, `host:port`.
    pub addr: String,
    /// The one action to perform.
    pub action: ClientAction,
    /// Poll timeout for [`ClientAction::Wait`].
    pub wait_timeout: Duration,
}

/// Resolve the daemon address: an explicit `--addr` wins; otherwise read
/// the `<state>/addr` file the daemon writes on startup.
pub fn resolve_addr(addr: Option<&str>, state: Option<&Path>) -> Result<String, String> {
    if let Some(a) = addr {
        return Ok(a.to_string());
    }
    let Some(root) = state else {
        return Err("need --addr HOST:PORT or --state DIR (to read its addr file)".into());
    };
    let path = StateDirs::new(root).addr_path();
    std::fs::read_to_string(&path)
        .map(|s| s.trim().to_string())
        .map_err(|e| format!("read {}: {e}", path.display()))
}

fn expect_ok(status: u16, body: &Json) -> Result<(), String> {
    if (200..300).contains(&status) {
        return Ok(());
    }
    let msg = body.get("error").and_then(|x| x.as_str()).unwrap_or("unknown error");
    Err(format!("daemon returned {status}: {msg}"))
}

/// Run one client action against the daemon; prints the daemon's JSON
/// answer to stdout (CI parses it with `python3 -c "import json,…"`).
pub fn run_client(opts: &ClientOpts) -> Result<(), String> {
    let addr = opts.addr.as_str();
    match &opts.action {
        ClientAction::Health => {
            let (status, body) = http::client_request(addr, "GET", "/v1/healthz", None)?;
            expect_ok(status, &body)?;
            println!("{}", body.to_string_pretty());
        }
        ClientAction::Submit(spec) => {
            let text = if Path::new(spec).is_file() {
                std::fs::read_to_string(spec).map_err(|e| format!("read {spec}: {e}"))?
            } else {
                spec.clone()
            };
            let doc = Json::parse(&text)?;
            let (status, body) = http::client_request(addr, "POST", "/v1/jobs", Some(&doc))?;
            expect_ok(status, &body)?;
            println!("{}", body.to_string_pretty());
        }
        ClientAction::Status(id) => {
            let path =
                if id.is_empty() { "/v1/jobs".to_string() } else { format!("/v1/jobs/{id}") };
            let (status, body) = http::client_request(addr, "GET", &path, None)?;
            expect_ok(status, &body)?;
            println!("{}", body.to_string_pretty());
        }
        ClientAction::Wait(id) => {
            if id.is_empty() {
                return Err("--wait needs a job id".into());
            }
            let deadline = Instant::now() + opts.wait_timeout;
            loop {
                let (status, body) =
                    http::client_request(addr, "GET", &format!("/v1/jobs/{id}"), None)?;
                expect_ok(status, &body)?;
                let st = body.get("status").and_then(|x| x.as_str()).unwrap_or("");
                if st == "done" {
                    println!("{}", body.to_string_pretty());
                    return Ok(());
                }
                if st == "failed" {
                    let why = body.get("error").and_then(|x| x.as_str()).unwrap_or("?");
                    return Err(format!("job {id} failed: {why}"));
                }
                if Instant::now() >= deadline {
                    return Err(format!("timed out waiting for job {id} (last status {st:?})"));
                }
                std::thread::sleep(Duration::from_millis(200));
            }
        }
        ClientAction::Trials(id) => {
            if id.is_empty() {
                return Err("--trials needs a job id".into());
            }
            let (status, body) =
                http::client_request(addr, "GET", &format!("/v1/jobs/{id}/trials"), None)?;
            expect_ok(status, &body)?;
            println!("{}", body.to_string_pretty());
        }
        ClientAction::Db(out) => {
            let (status, body) = http::client_request(addr, "GET", "/v1/db", None)?;
            expect_ok(status, &body)?;
            match out {
                Some(path) => {
                    crate::fsio::write_atomic(path, &body.to_string_pretty())
                        .map_err(|e| e.to_string())?;
                    println!("wrote crowd db to {}", path.display());
                }
                None => println!("{}", body.to_string_pretty()),
            }
        }
        ClientAction::Drain => {
            let (status, body) = http::client_request(addr, "POST", "/v1/drain", None)?;
            expect_ok(status, &body)?;
            println!("{}", body.to_string_pretty());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::TunerKind;
    use crate::objective::TimingMode;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ranntune_serve_{tag}_{}", std::process::id()))
    }

    /// End-to-end over real sockets: submit two jobs through the route
    /// table, drive them, and read state/trials/db back out.
    #[test]
    fn routes_cover_the_job_lifecycle() {
        let dir = tmp("routes");
        let _ = std::fs::remove_dir_all(&dir);
        let sched =
            Scheduler::open(StateDirs::new(&dir), ServeConfig::default()).unwrap();

        let mut manifest = JobManifest::new("GA", 240, 10, TunerKind::Lhsmdu);
        manifest.budget = 3;
        manifest.repeats = 1;
        manifest.timing = TimingMode::Modeled;
        let submit = Request {
            method: "POST".into(),
            path: "/v1/jobs".into(),
            query: Default::default(),
            body: manifest.to_json().to_string_pretty(),
        };
        let (status, body) = route(&sched, &submit);
        assert_eq!(status, 202, "{body:?}");
        let id = body.get("id").and_then(|x| x.as_str()).unwrap().to_string();
        assert_eq!(body.get("status").and_then(|x| x.as_str()), Some("queued"));

        sched.run_until_idle(1);

        let get = |path: &str| {
            route(
                &sched,
                &Request {
                    method: "GET".into(),
                    path: path.into(),
                    query: Default::default(),
                    body: String::new(),
                },
            )
        };
        let (status, state) = get(&format!("/v1/jobs/{id}"));
        assert_eq!(status, 200);
        assert_eq!(state.get("status").and_then(|x| x.as_str()), Some("done"));
        let (status, trials) = get(&format!("/v1/jobs/{id}/trials"));
        assert_eq!(status, 200);
        assert_eq!(trials.get("total").and_then(|x| x.as_f64()), Some(3.0));
        let (status, db) = get("/v1/db");
        assert_eq!(status, 200);
        assert!(db.get("tasks").is_some());
        let (status, list) = get("/v1/jobs");
        assert_eq!(status, 200);
        assert_eq!(list.get("jobs").and_then(|x| x.as_arr()).unwrap().len(), 1);
        let (status, _) = get("/v1/jobs/job-999999");
        assert_eq!(status, 404);
        let (status, health) = get("/v1/healthz");
        assert_eq!(status, 200);
        assert_eq!(health.get("ok").and_then(|x| x.as_bool()), Some(true));

        // Drain via the route; further submissions are refused.
        let drain = Request {
            method: "POST".into(),
            path: "/v1/drain".into(),
            query: Default::default(),
            body: String::new(),
        };
        assert_eq!(route(&sched, &drain).0, 202);
        let (status, body) = route(&sched, &submit);
        assert_eq!(status, 400, "{body:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resolve_addr_prefers_flag_then_state_file() {
        let dir = tmp("addr");
        let _ = std::fs::remove_dir_all(&dir);
        assert_eq!(resolve_addr(Some("1.2.3.4:80"), None).unwrap(), "1.2.3.4:80");
        assert!(resolve_addr(None, None).is_err());
        let dirs = StateDirs::new(&dir);
        dirs.init().unwrap();
        crate::fsio::write_atomic(&dirs.addr_path(), "127.0.0.1:4567\n").unwrap();
        assert_eq!(resolve_addr(None, Some(&dir)).unwrap(), "127.0.0.1:4567");
        std::fs::remove_dir_all(&dir).ok();
    }
}
