//! Job manifests and on-disk job state of the serving daemon.
//!
//! A **job manifest** (`ranntune-job-v1`) is the wire format a tenant
//! submits to `POST /v1/jobs`: a problem fingerprint (dataset, shape,
//! data seed — exactly the [`crate::data::ProblemSpec`] identity), a
//! tuner, a budget, and the execution knobs. Serialization goes through
//! [`crate::json::Json`], whose objects are `BTreeMap`s — key order is
//! sorted and therefore stable across versions and writers.
//!
//! A **job state** file (`ranntune-jobstate-v1`, one per job under
//! `<state>/jobs/`) is the daemon's durable record: the manifest, the
//! lifecycle status, and the warm-start trial snapshot taken from the
//! crowd database at submission time. Snapshotting at submission — not
//! at first slice — makes a job's results a pure function of its state
//! file: a daemon killed and restarted re-runs the job with the identical
//! warm set, which the byte-identical-restart guarantee depends on.

use crate::campaign::{Cell, TunerKind};
use crate::data::{ProblemSpec, Regime};
use crate::json::Json;
use crate::objective::{TimingMode, Trial};
use std::path::{Path, PathBuf};

/// Format tag of the submitted manifest document.
pub const JOB_FORMAT: &str = "ranntune-job-v1";
/// Format tag of the daemon's per-job state file.
pub const JOBSTATE_FORMAT: &str = "ranntune-jobstate-v1";

/// A tuning-job request, as submitted by a tenant.
#[derive(Clone, Debug, PartialEq)]
pub struct JobManifest {
    /// Fair-share accounting unit; jobs of one tenant never hold more
    /// than the daemon's per-tenant cap of concurrent slices.
    pub tenant: String,
    /// Dataset name accepted by [`crate::data::build_problem`].
    pub dataset: String,
    /// Rows of A.
    pub m: usize,
    /// Columns of A.
    pub n: usize,
    /// Seed of the data-generation stream (part of the problem identity).
    pub data_seed: u64,
    /// Regime tag carried through to reports (not result-relevant).
    pub regime: Regime,
    /// Problem family this job tunes ([`crate::families::get`] name).
    /// Part of the problem identity: a non-default family prefixes the
    /// crowd fingerprint, so e.g. ridge and least-squares trials on the
    /// same matrix never warm-start each other.
    pub family: String,
    /// Which tuner to run.
    pub tuner: TunerKind,
    /// Evaluation budget (the reference counts as the first).
    pub budget: usize,
    /// Job seed; the session's streams derive from it exactly like a
    /// campaign cell's ([`Cell::seed`]).
    pub seed: u64,
    /// Solver repeats averaged per evaluation.
    pub repeats: usize,
    /// Measured (the paper's objective) or deterministic modeled timing.
    pub timing: TimingMode,
    /// Warm-start the tuner from the crowd database's records of this
    /// problem fingerprint (any shape), snapshotted at submission.
    pub warm: bool,
    /// TLA only: LHSMDU samples pre-collected on the source sibling.
    pub source_samples: usize,
    /// Threads for within-session batch evaluation (1 = serial).
    pub eval_threads: usize,
}

impl JobManifest {
    /// A manifest with the service defaults for everything but the
    /// problem identity and tuner.
    pub fn new(dataset: &str, m: usize, n: usize, tuner: TunerKind) -> JobManifest {
        JobManifest {
            tenant: "anon".into(),
            dataset: dataset.into(),
            m,
            n,
            data_seed: 1,
            regime: Regime::LowCoherence,
            family: "sap-ls".into(),
            tuner,
            budget: 20,
            seed: 0,
            repeats: 3,
            timing: TimingMode::Measured,
            warm: false,
            source_samples: 30,
            eval_threads: 1,
        }
    }

    /// The problem spec this job tunes (identity = dataset + shape +
    /// data seed, the conventional `"{dataset}-{m}x{n}-s{seed}"` id).
    pub fn problem(&self) -> ProblemSpec {
        ProblemSpec::new(&self.dataset, self.m, self.n, self.data_seed, self.regime)
            .with_family(&self.family)
    }

    /// The problem fingerprint keying this job's trials in the crowd
    /// database — later jobs on the same fingerprint warm-start from
    /// them and TLA transfer-learns.
    pub fn problem_id(&self) -> String {
        self.problem().id
    }

    /// Deterministic seed of the job's session streams: the campaign
    /// cell derivation ([`Cell::seed`]) applied to (problem, tuner,
    /// job seed), so a job's recorded trials depend only on its
    /// manifest — never on scheduling.
    pub fn session_seed(&self) -> u64 {
        Cell { problem: self.problem(), tuner: self.tuner }.seed(self.seed)
    }

    /// Serialize to the `ranntune-job-v1` wire document. The `family`
    /// key is only emitted for non-default families, so documents (and
    /// their state files) written before families existed stay
    /// byte-identical.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("format", Json::Str(JOB_FORMAT.into())),
            ("tenant", Json::Str(self.tenant.clone())),
            ("dataset", Json::Str(self.dataset.clone())),
            ("m", Json::Num(self.m as f64)),
            ("n", Json::Num(self.n as f64)),
            ("data_seed", Json::Num(self.data_seed as f64)),
            ("regime", Json::Str(self.regime.name().into())),
            ("tuner", Json::Str(self.tuner.name().to_ascii_lowercase())),
            ("budget", Json::Num(self.budget as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("repeats", Json::Num(self.repeats as f64)),
            ("timing", Json::Str(self.timing.name().into())),
            ("warm", Json::Bool(self.warm)),
            ("source_samples", Json::Num(self.source_samples as f64)),
            ("eval_threads", Json::Num(self.eval_threads as f64)),
        ];
        if self.family != "sap-ls" {
            pairs.push(("family", Json::Str(self.family.clone())));
        }
        Json::obj(pairs)
    }

    /// Parse a manifest. Only the problem identity (`dataset`, `m`, `n`)
    /// and `tuner` are required; every other field defaults as in
    /// [`JobManifest::new`]. An unknown `format` tag is refused so a
    /// future v2 document is never silently half-read, and unknown
    /// top-level keys are refused so a typoed knob (`"budgit"`) fails
    /// loudly instead of silently tuning with the default.
    pub fn from_json(v: &Json) -> Result<JobManifest, String> {
        const KNOWN_KEYS: [&str; 16] = [
            "format",
            "tenant",
            "dataset",
            "m",
            "n",
            "data_seed",
            "regime",
            "family",
            "tuner",
            "budget",
            "seed",
            "repeats",
            "timing",
            "warm",
            "source_samples",
            "eval_threads",
        ];
        if let Json::Obj(map) = v {
            let unknown: Vec<&str> = map
                .keys()
                .map(String::as_str)
                .filter(|k| !KNOWN_KEYS.contains(k))
                .collect();
            if !unknown.is_empty() {
                return Err(format!("job: unknown manifest keys: {}", unknown.join(", ")));
            }
        }
        if let Some(f) = v.get("format").and_then(|x| x.as_str()) {
            if f != JOB_FORMAT {
                return Err(format!("unsupported job format {f:?} (want {JOB_FORMAT})"));
            }
        }
        let dataset =
            v.get("dataset").and_then(|x| x.as_str()).ok_or("job: missing dataset")?;
        let m = v.get("m").and_then(|x| x.as_usize()).ok_or("job: missing m")?;
        let n = v.get("n").and_then(|x| x.as_usize()).ok_or("job: missing n")?;
        let tuner = v
            .get("tuner")
            .and_then(|x| x.as_str())
            .and_then(TunerKind::parse)
            .ok_or("job: missing or unknown tuner")?;
        let mut job = JobManifest::new(dataset, m, n, tuner);
        if let Some(t) = v.get("tenant").and_then(|x| x.as_str()) {
            job.tenant = t.to_string();
        }
        if let Some(s) = v.get("data_seed").and_then(|x| x.as_f64()) {
            job.data_seed = s as u64;
        }
        if let Some(r) = v.get("regime").and_then(|x| x.as_str()) {
            job.regime = Regime::parse(r).ok_or_else(|| format!("job: unknown regime {r:?}"))?;
        }
        if let Some(f) = v.get("family").and_then(|x| x.as_str()) {
            if crate::families::get(f).is_none() {
                return Err(format!(
                    "job: unknown family {f:?} (want {})",
                    crate::families::known_names()
                ));
            }
            job.family = f.to_string();
        }
        if let Some(b) = v.get("budget").and_then(|x| x.as_usize()) {
            job.budget = b;
        }
        if let Some(s) = v.get("seed").and_then(|x| x.as_f64()) {
            job.seed = s as u64;
        }
        if let Some(r) = v.get("repeats").and_then(|x| x.as_usize()) {
            job.repeats = r;
        }
        if let Some(t) = v.get("timing").and_then(|x| x.as_str()) {
            job.timing =
                TimingMode::parse(t).ok_or_else(|| format!("job: unknown timing {t:?}"))?;
        }
        if let Some(w) = v.get("warm").and_then(|x| x.as_bool()) {
            job.warm = w;
        }
        if let Some(s) = v.get("source_samples").and_then(|x| x.as_usize()) {
            job.source_samples = s;
        }
        if let Some(e) = v.get("eval_threads").and_then(|x| x.as_usize()) {
            job.eval_threads = e;
        }
        if job.budget == 0 {
            return Err("job: budget must be at least 1".into());
        }
        if job.n == 0 || job.m <= job.n {
            return Err(format!("job: need m > n > 0, got {}x{}", job.m, job.n));
        }
        Ok(job)
    }
}

/// Lifecycle of a job inside the daemon.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    /// Accepted; waiting for a scheduler slice.
    Queued,
    /// At least one slice has run; the session checkpoint tracks progress.
    Running,
    /// Completed; its shard is folded into the crowd database.
    Done,
    /// The session errored (e.g. an unbuildable dataset).
    Failed,
}

impl JobStatus {
    /// Stable lower-case label (wire format and state files).
    pub fn name(&self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Done => "done",
            JobStatus::Failed => "failed",
        }
    }

    /// Inverse of [`JobStatus::name`].
    pub fn parse(s: &str) -> Option<JobStatus> {
        match s {
            "queued" => Some(JobStatus::Queued),
            "running" => Some(JobStatus::Running),
            "done" => Some(JobStatus::Done),
            "failed" => Some(JobStatus::Failed),
            _ => None,
        }
    }

    /// Has the job reached a terminal state?
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobStatus::Done | JobStatus::Failed)
    }
}

/// Durable record of one accepted job.
#[derive(Clone, Debug)]
pub struct JobState {
    /// Zero-padded sequence id (`job-000001`); doubles as the shard file
    /// name and — being sortable — the deterministic crowd-fold order.
    pub id: String,
    /// The submitted manifest.
    pub manifest: JobManifest,
    /// Lifecycle status.
    pub status: JobStatus,
    /// Error text when `status` is [`JobStatus::Failed`].
    pub error: Option<String>,
    /// Warm-start trials snapshotted from the crowd database at
    /// submission (empty when the manifest's `warm` is false).
    pub warm_trials: Vec<Trial>,
}

impl JobState {
    /// Serialize to the `ranntune-jobstate-v1` document with the live
    /// in-memory status — what the HTTP API returns.
    pub fn to_json(&self) -> Json {
        self.json_with_status(self.status)
    }

    /// Serialize for the durable state file. An in-memory
    /// [`JobStatus::Running`] persists as `queued`: a restarted daemon
    /// cannot distinguish the two (both mean "resume from the session
    /// checkpoint"), so the state file never claims more than it knows.
    fn disk_json(&self) -> Json {
        let disk_status = match self.status {
            JobStatus::Running => JobStatus::Queued,
            s => s,
        };
        self.json_with_status(disk_status)
    }

    fn json_with_status(&self, disk_status: JobStatus) -> Json {
        let mut pairs = vec![
            ("format", Json::Str(JOBSTATE_FORMAT.into())),
            ("id", Json::Str(self.id.clone())),
            ("manifest", self.manifest.to_json()),
            ("status", Json::Str(disk_status.name().into())),
            (
                "warm_trials",
                Json::Arr(self.warm_trials.iter().map(Trial::to_json).collect()),
            ),
        ];
        if let Some(e) = &self.error {
            pairs.push(("error", Json::Str(e.clone())));
        }
        Json::obj(pairs)
    }

    /// Parse a state document.
    pub fn from_json(v: &Json) -> Result<JobState, String> {
        let id = v
            .get("id")
            .and_then(|x| x.as_str())
            .ok_or("job state: missing id")?
            .to_string();
        let manifest =
            JobManifest::from_json(v.get("manifest").ok_or("job state: missing manifest")?)?;
        let status = v
            .get("status")
            .and_then(|x| x.as_str())
            .and_then(JobStatus::parse)
            .ok_or("job state: missing status")?;
        let warm_trials = v
            .get("warm_trials")
            .and_then(|x| x.as_arr())
            .unwrap_or(&[])
            .iter()
            .map(Trial::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        let error = v.get("error").and_then(|x| x.as_str()).map(str::to_string);
        Ok(JobState { id, manifest, status, error, warm_trials })
    }

    /// Durably persist under the daemon's state directory.
    pub fn save(&self, dirs: &StateDirs) -> Result<(), String> {
        crate::fsio::write_atomic(&dirs.job_path(&self.id), &self.disk_json().to_string_pretty())
            .map_err(|e| e.to_string())
    }
}

/// The daemon's on-disk layout, rooted at `--state`:
///
/// ```text
/// <state>/
///   jobs/<job-id>.json      # durable job state (manifest + status + warm set)
///   sessions/<job-id>.json  # mid-run session checkpoint (batch granular)
///   shards/<job-id>.json    # per-job HistoryDb, written on completion
///   crowd.json              # fold of done-job shards, in job-id order
///   addr                    # "host:port" of the live daemon (for clients)
/// ```
#[derive(Clone, Debug)]
pub struct StateDirs {
    root: PathBuf,
}

impl StateDirs {
    /// Bind to a state root (directories are created by [`StateDirs::init`]).
    pub fn new(root: &Path) -> StateDirs {
        StateDirs { root: root.to_path_buf() }
    }

    /// The state root.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Create the layout's directories.
    pub fn init(&self) -> Result<(), String> {
        for d in ["jobs", "sessions", "shards"] {
            std::fs::create_dir_all(self.root.join(d)).map_err(|e| e.to_string())?;
        }
        Ok(())
    }

    /// Path of a job's durable state file.
    pub fn job_path(&self, id: &str) -> PathBuf {
        self.root.join("jobs").join(format!("{id}.json"))
    }

    /// Path of a job's mid-run session checkpoint.
    pub fn session_path(&self, id: &str) -> PathBuf {
        self.root.join("sessions").join(format!("{id}.json"))
    }

    /// Path of a job's completed-trials shard.
    pub fn shard_path(&self, id: &str) -> PathBuf {
        self.root.join("shards").join(format!("{id}.json"))
    }

    /// Path of the shared crowd database.
    pub fn crowd_path(&self) -> PathBuf {
        self.root.join("crowd.json")
    }

    /// Path of the live daemon's address file.
    pub fn addr_path(&self) -> PathBuf {
        self.root.join("addr")
    }

    /// Load every persisted job state, sorted by job id.
    pub fn load_jobs(&self) -> Result<Vec<JobState>, String> {
        let dir = self.root.join("jobs");
        let mut out = Vec::new();
        let Ok(entries) = std::fs::read_dir(&dir) else {
            return Ok(out);
        };
        let mut paths: Vec<PathBuf> = entries
            .flatten()
            .map(|e| e.path())
            .filter(|p| p.extension().is_some_and(|e| e == "json"))
            .collect();
        paths.sort();
        for p in paths {
            let text = std::fs::read_to_string(&p).map_err(|e| e.to_string())?;
            out.push(JobState::from_json(&crate::json::Json::parse(&text)?)?);
        }
        Ok(out)
    }

    /// Allocate the next job id: one past the highest persisted sequence
    /// number, zero-padded so lexicographic order is submission order.
    pub fn next_job_id(&self) -> String {
        let mut max = 0u64;
        if let Ok(entries) = std::fs::read_dir(self.root.join("jobs")) {
            for e in entries.flatten() {
                let name = e.file_name();
                let name = name.to_string_lossy();
                if let Some(seq) = name
                    .strip_prefix("job-")
                    .and_then(|s| s.strip_suffix(".json"))
                    .and_then(|s| s.parse::<u64>().ok())
                {
                    max = max.max(seq);
                }
            }
        }
        format!("job-{:06}", max + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_round_trips_and_defaults_apply() {
        let mut m = JobManifest::new("GA", 300, 15, TunerKind::Tpe);
        m.tenant = "team-a".into();
        m.budget = 8;
        m.timing = TimingMode::Modeled;
        m.warm = true;
        let back = JobManifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
        // Minimal document: only problem identity + tuner required.
        let minimal =
            Json::parse(r#"{"dataset":"GA","m":200,"n":10,"tuner":"lhsmdu"}"#).unwrap();
        let j = JobManifest::from_json(&minimal).unwrap();
        assert_eq!(j.tenant, "anon");
        assert_eq!(j.budget, 20);
        assert_eq!(j.timing, TimingMode::Measured);
        assert_eq!(j.family, "sap-ls");
        assert_eq!(j.problem_id(), "GA-200x10-s1");
    }

    #[test]
    fn family_round_trips_and_prefixes_the_problem_id() {
        // Default family is omitted from the wire document entirely.
        let m = JobManifest::new("GA", 300, 15, TunerKind::Tpe);
        assert!(!m.to_json().to_string_pretty().contains("family"));
        // Non-default families round-trip and prefix the crowd key.
        let mut r = m.clone();
        r.family = "ridge".into();
        let back = JobManifest::from_json(&r.to_json()).unwrap();
        assert_eq!(back, r);
        assert_eq!(back.problem_id(), "ridge.GA-300x15-s1");
        // The family shifts the session seed (different problem identity).
        assert_ne!(m.session_seed(), r.session_seed());
    }

    #[test]
    fn manifest_rejects_unknown_keys_naming_the_offenders() {
        let doc = Json::parse(
            r#"{"dataset":"GA","m":200,"n":10,"tuner":"tpe","budgit":9,"warm_start":true}"#,
        )
        .unwrap();
        let err = JobManifest::from_json(&doc).unwrap_err();
        assert!(err.contains("unknown manifest keys"), "{err}");
        assert!(err.contains("budgit"), "{err}");
        assert!(err.contains("warm_start"), "{err}");
    }

    #[test]
    fn manifest_rejects_bad_documents() {
        for bad in [
            r#"{"m":200,"n":10,"tuner":"lhsmdu"}"#,
            r#"{"dataset":"GA","m":200,"n":10,"tuner":"nope"}"#,
            r#"{"dataset":"GA","m":200,"n":10,"tuner":"tpe","budget":0}"#,
            r#"{"dataset":"GA","m":10,"n":10,"tuner":"tpe"}"#,
            r#"{"dataset":"GA","m":200,"n":10,"tuner":"tpe","timing":"warp"}"#,
            r#"{"format":"ranntune-job-v9","dataset":"GA","m":200,"n":10,"tuner":"tpe"}"#,
            r#"{"dataset":"GA","m":200,"n":10,"tuner":"tpe","family":"poisson"}"#,
        ] {
            assert!(JobManifest::from_json(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn session_seed_matches_campaign_cell_derivation() {
        let m = JobManifest::new("GA", 300, 15, TunerKind::Tpe);
        let cell = Cell { problem: m.problem(), tuner: TunerKind::Tpe };
        assert_eq!(m.session_seed(), cell.seed(m.seed));
        // Seed depends on the problem identity and tuner.
        let mut other = m.clone();
        other.data_seed += 1;
        assert_ne!(m.session_seed(), other.session_seed());
    }

    #[test]
    fn job_state_round_trips_and_running_persists_as_queued() {
        let dirs_root =
            std::env::temp_dir().join(format!("ranntune_jobstate_{}", std::process::id()));
        let dirs = StateDirs::new(&dirs_root);
        dirs.init().unwrap();
        let state = JobState {
            id: "job-000001".into(),
            manifest: JobManifest::new("GA", 300, 15, TunerKind::Lhsmdu),
            status: JobStatus::Running,
            error: None,
            warm_trials: Vec::new(),
        };
        state.save(&dirs).unwrap();
        let loaded = dirs.load_jobs().unwrap();
        assert_eq!(loaded.len(), 1);
        assert_eq!(loaded[0].id, "job-000001");
        // Running collapses to queued on disk: a restart must re-drive it.
        assert_eq!(loaded[0].status, JobStatus::Queued);
        assert_eq!(dirs.next_job_id(), "job-000002");
        std::fs::remove_dir_all(&dirs_root).ok();
    }

    #[test]
    fn job_ids_sort_in_submission_order() {
        let dirs_root =
            std::env::temp_dir().join(format!("ranntune_jobids_{}", std::process::id()));
        let dirs = StateDirs::new(&dirs_root);
        dirs.init().unwrap();
        assert_eq!(dirs.next_job_id(), "job-000001");
        for i in 1..=11u64 {
            std::fs::write(dirs.job_path(&format!("job-{i:06}")), "{}").unwrap();
        }
        assert_eq!(dirs.next_job_id(), "job-000012");
        std::fs::remove_dir_all(&dirs_root).ok();
    }
}
