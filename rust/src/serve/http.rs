//! Minimal pure-std HTTP/1.1 plumbing for the tuning daemon.
//!
//! The crate has a no-dependency policy, so this is a deliberately tiny
//! subset of HTTP — exactly what `ranntune serve` and its CI client
//! need: one request per connection (`Connection: close`), JSON bodies,
//! `Content-Length` framing, no chunked encoding, no keep-alive, no
//! TLS. Both sides of the conversation live here so the daemon and the
//! client can never disagree about framing.

use crate::json::Json;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Cap on accepted request bodies (a tuning manifest is < 1 KiB; this
/// bound keeps a misbehaving client from ballooning daemon memory).
const MAX_BODY: usize = 1 << 20;

/// A parsed inbound HTTP request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Request method, upper-case (`GET`, `POST`, …).
    pub method: String,
    /// Decoded path without the query string, e.g. `/v1/jobs`.
    pub path: String,
    /// Parsed query parameters (`?since=5` ⇒ `{"since": "5"}`).
    pub query: BTreeMap<String, String>,
    /// Raw request body (empty when none was sent).
    pub body: String,
}

impl Request {
    /// The request body parsed as JSON.
    pub fn json(&self) -> Result<Json, String> {
        Json::parse(&self.body)
    }
}

/// Read and parse one HTTP request from a connection. Returns an error
/// on malformed framing, over-long bodies, or I/O failure; the caller
/// just drops the connection.
pub fn read_request(stream: &mut TcpStream) -> Result<Request, String> {
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .map_err(|e| e.to_string())?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).map_err(|e| e.to_string())?;
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or("empty request line")?.to_ascii_uppercase();
    let target = parts.next().ok_or("request line has no target")?;
    let (path, query_str) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    let mut query = BTreeMap::new();
    for pair in query_str.split('&').filter(|s| !s.is_empty()) {
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        query.insert(k.to_string(), v.to_string());
    }
    let mut content_len = 0usize;
    loop {
        let mut header = String::new();
        reader.read_line(&mut header).map_err(|e| e.to_string())?;
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_len = value
                    .trim()
                    .parse::<usize>()
                    .map_err(|_| format!("bad content-length {value:?}"))?;
            }
        }
    }
    if content_len > MAX_BODY {
        return Err(format!("request body of {content_len} bytes exceeds cap"));
    }
    let mut body = vec![0u8; content_len];
    reader.read_exact(&mut body).map_err(|e| e.to_string())?;
    Ok(Request {
        method,
        path: path.to_string(),
        query,
        body: String::from_utf8(body).map_err(|_| "request body is not UTF-8")?,
    })
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        409 => "Conflict",
        _ => "Internal Server Error",
    }
}

/// Write a JSON response and flush. Errors are returned for logging but
/// the connection is closed either way.
pub fn respond(stream: &mut TcpStream, status: u16, body: &Json) -> std::io::Result<()> {
    let text = body.to_string_pretty();
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        reason(status),
        text.len(),
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(text.as_bytes())?;
    stream.flush()
}

/// Client side: send one request to `addr` (`host:port`) and return
/// `(status, parsed JSON body)`. Used by `ranntune client` and the CI
/// smoke test; retries are the caller's business.
pub fn client_request(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&Json>,
) -> Result<(u16, Json), String> {
    let mut stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .map_err(|e| e.to_string())?;
    let payload = body.map(|b| b.to_string_pretty()).unwrap_or_default();
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        payload.len(),
    );
    stream.write_all(head.as_bytes()).map_err(|e| e.to_string())?;
    stream.write_all(payload.as_bytes()).map_err(|e| e.to_string())?;
    stream.flush().map_err(|e| e.to_string())?;

    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).map_err(|e| e.to_string())?;
    let status = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| format!("bad status line {status_line:?}"))?;
    let mut content_len: Option<usize> = None;
    loop {
        let mut header = String::new();
        reader.read_line(&mut header).map_err(|e| e.to_string())?;
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_len = value.trim().parse::<usize>().ok();
            }
        }
    }
    let mut body = Vec::new();
    match content_len {
        Some(len) => {
            body.resize(len, 0);
            reader.read_exact(&mut body).map_err(|e| e.to_string())?;
        }
        None => {
            reader.read_to_end(&mut body).map_err(|e| e.to_string())?;
        }
    }
    let text = String::from_utf8(body).map_err(|_| "response body is not UTF-8")?;
    let json = if text.trim().is_empty() { Json::Null } else { Json::parse(&text)? };
    Ok((status, json))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// One round trip through both halves of the plumbing: the client
    /// writer feeds the server parser and vice versa.
    #[test]
    fn request_and_response_round_trip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (mut conn, _) = listener.accept().unwrap();
            let req = read_request(&mut conn).unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.path, "/v1/echo");
            assert_eq!(req.query.get("since").map(String::as_str), Some("5"));
            let doc = req.json().unwrap();
            respond(&mut conn, 200, &doc).unwrap();
        });
        let body = Json::obj(vec![("x", Json::Num(7.0))]);
        let (status, echoed) =
            client_request(&addr, "POST", "/v1/echo?since=5", Some(&body)).unwrap();
        assert_eq!(status, 200);
        assert_eq!(echoed.to_string_pretty(), body.to_string_pretty());
        server.join().unwrap();
    }

    #[test]
    fn bodyless_get_parses() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (mut conn, _) = listener.accept().unwrap();
            let req = read_request(&mut conn).unwrap();
            assert_eq!(req.method, "GET");
            assert!(req.body.is_empty());
            respond(&mut conn, 404, &Json::Str("no such job".into())).unwrap();
        });
        let (status, body) = client_request(&addr, "GET", "/v1/jobs/job-9", None).unwrap();
        assert_eq!(status, 404);
        assert_eq!(body.as_str(), Some("no such job"));
        server.join().unwrap();
    }
}
