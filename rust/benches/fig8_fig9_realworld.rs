//! Bench: regenerate Figures 8 & 9 (real-world landscape + tuner comparison).
mod common;

fn main() {
    let scale = common::bench_scale();
    let out = common::results_dir();
    println!("== Figure 8 (scale: {}) ==", scale.label);
    println!(
        "{}",
        ranntune::cli::figures::grid_figure(
            &scale,
            &["Musk", "CIFAR10", "Localization"],
            "fig8",
            &out
        )
    );
    println!("== Figure 9 ==");
    println!(
        "{}",
        ranntune::cli::figures::tuner_figure(
            &scale,
            &["Musk", "CIFAR10", "Localization"],
            "fig9",
            &out
        )
    );
}
