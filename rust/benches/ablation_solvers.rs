//! Ablation: iterative-method variants on the same preconditioner —
//! LSQR vs PGD vs PGD+momentum vs Chebyshev semi-iteration (the
//! Appendix A.2/A.3 design space). Reports iterations and wall-clock to
//! reach ρ = 1e-8 for strong and weak sketches.

mod common;

use ranntune::bench_harness::{fmt_secs, markdown_table, time_fn};
use ranntune::data::{generate_synthetic, SyntheticKind};
use ranntune::rng::Rng;
use ranntune::sap::{
    chebyshev_preconditioned, default_spectrum_bounds, lsqr_preconditioned,
    pgd_momentum_preconditioned, pgd_preconditioned, Preconditioner,
};
use ranntune::sketch::{make_sketch, SketchKind};

fn main() {
    let scale = common::bench_scale();
    let (m, n) = (scale.m.max(2000), scale.n.max(64));
    let mut rng = Rng::new(3);
    let problem = generate_synthetic(SyntheticKind::GA, m, n, &mut rng);
    println!("== solver ablation (m={m}, n={n}) ==\n");

    let mut rows = Vec::new();
    for (regime, d) in [("strong sketch (d=4n)", 4 * n), ("weak sketch (d=3n/2)", 3 * n / 2)] {
        let op = make_sketch(SketchKind::Sjlt, d, m, 8, &mut rng);
        let sketch = op.apply(problem.dense());
        let p = Preconditioner::from_svd(&sketch);
        let z0 = vec![0.0; p.rank()];
        let bounds = default_spectrum_bounds(d, n);
        let tol = 1e-8;
        let iters = 3000;

        type Runner<'a> = Box<dyn Fn() -> (usize, bool) + 'a>;
        let variants: Vec<(&str, Runner)> = vec![
            ("LSQR", Box::new(|| {
                let r = lsqr_preconditioned(problem.dense(), problem.b(), &p, &z0, tol, iters);
                (r.iterations, r.converged)
            })),
            ("PGD", Box::new(|| {
                let r = pgd_preconditioned(problem.dense(), problem.b(), &p, &z0, tol, iters);
                (r.iterations, r.converged)
            })),
            ("PGD+momentum", Box::new(|| {
                let r = pgd_momentum_preconditioned(
                    problem.dense(), problem.b(), &p, &z0, bounds, tol, iters,
                );
                (r.iterations, r.converged)
            })),
            ("Chebyshev", Box::new(|| {
                let r = chebyshev_preconditioned(
                    problem.dense(), problem.b(), &p, &z0, bounds, tol, iters,
                );
                (r.iterations, r.converged)
            })),
        ];
        for (name, run) in &variants {
            let (its, conv) = run();
            let stats = time_fn(1, 3, || {
                std::hint::black_box(run());
            });
            rows.push(vec![
                regime.to_string(),
                name.to_string(),
                format!("{its}{}", if conv { "" } else { " (limit)" }),
                fmt_secs(stats.median),
            ]);
        }
    }
    let headers = ["regime", "method", "iterations to 1e-8", "median time"];
    println!("{}", markdown_table(&headers, &rows));
    let _ = ranntune::bench_harness::write_result(
        &common::results_dir(),
        "ablation_solvers",
        "Iterative-method ablation (Appendix A design space)",
        &headers,
        &rows,
    );
}
