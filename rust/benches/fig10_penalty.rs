//! Bench: regenerate Figure 10 (allowance/penalty-factor ablation).
mod common;

fn main() {
    let scale = common::bench_scale();
    println!("== Figure 10 (scale: {}) ==", scale.label);
    println!("{}", ranntune::cli::figures::fig10(&scale, &common::results_dir()));
}
