//! Micro-benchmarks of the L3 hot paths: sketch-apply (both operators),
//! preconditioner factorizations, LSQR/PGD iterations, the full SAP solve,
//! and GP fit/propose. These are the §Perf before/after numbers in
//! EXPERIMENTS.md.

mod common;

use ranntune::bench_harness::{fmt_secs, markdown_table, time_fn};
use ranntune::data::{generate_synthetic, SyntheticKind};
use ranntune::gp::GpModel;
use ranntune::linalg::{gemm, Mat};
use ranntune::rng::Rng;
use ranntune::sap::{solve_sap, Preconditioner, SapConfig};
use ranntune::sketch::{make_sketch, SketchKind, SketchOp};

/// Dimension override for CI smoke runs: RANNTUNE_BENCH_M / RANNTUNE_BENCH_N
/// shrink the problem below the interactive floor (the CI bench-smoke job
/// runs at a few hundred rows so the whole binary finishes in seconds).
fn env_dim(var: &str, default: usize) -> usize {
    std::env::var(var).ok().and_then(|s| s.parse().ok()).filter(|&v| v > 0).unwrap_or(default)
}

fn main() {
    let scale = common::bench_scale();
    let m = env_dim("RANNTUNE_BENCH_M", scale.m.max(2000));
    let n = env_dim("RANNTUNE_BENCH_N", scale.n.max(64)).min(m);
    let d = 4 * n;
    let mut rng = Rng::new(1);
    println!("== hot-path micro benches (m={m}, n={n}, d={d}) ==\n");

    let problem = generate_synthetic(SyntheticKind::GA, m, n, &mut rng);
    let a = &problem.a;
    // (name, median_s, min_s, gflops) — gflops 0.0 when no flop count
    // applies. The display table is derived from this after the runs.
    let mut raw: Vec<(String, f64, f64, f64)> = Vec::new();
    let mut add = |name: &str, stats: ranntune::bench_harness::TimingStats, flops: f64| {
        let gflops = if flops > 0.0 && stats.median > 0.0 {
            flops / stats.median / 1e9
        } else {
            0.0
        };
        raw.push((name.to_string(), stats.median, stats.min, gflops));
    };

    // Sketch applies: LessUniform (d·k·n flops) vs SJLT (m·k·n flops).
    for (kind, k) in [(SketchKind::LessUniform, 8usize), (SketchKind::Sjlt, 8)] {
        let op = make_sketch(kind, d, m, k, &mut rng);
        let flops = 2.0 * op.nnz() as f64 * n as f64;
        let stats = time_fn(2, 8, || {
            std::hint::black_box(op.apply(a));
        });
        add(&format!("sketch_apply {} k={k}", kind.name()), stats, flops);
    }

    // Preconditioner generation.
    let op = make_sketch(SketchKind::LessUniform, d, m, 8, &mut rng);
    let sketch = op.apply(a);
    let qr_flops = 2.0 * d as f64 * (n * n) as f64;
    add(
        "precond QR (d×n)",
        time_fn(1, 5, || {
            std::hint::black_box(Preconditioner::from_qr(&sketch));
        }),
        qr_flops,
    );
    add(
        "precond SVD (d×n)",
        time_fn(1, 3, || {
            std::hint::black_box(Preconditioner::from_svd(&sketch));
        }),
        qr_flops, // same order; reported as effective QR-equivalent rate
    );

    // One LSQR iteration ≈ one A·v + one Aᵀ·u (4mn flops) + O(n) vector ops.
    let precond = Preconditioner::from_qr(&sketch);
    let z0 = vec![0.0; precond.rank()];
    let iter_flops = 4.0 * (m * n) as f64;
    let stats = time_fn(1, 5, || {
        std::hint::black_box(ranntune::sap::lsqr_preconditioned(
            a,
            &problem.b,
            &precond,
            &z0,
            0.0,
            10,
        ));
    });
    add(
        "LSQR 10 iters (per-iter rate)",
        ranntune::bench_harness::TimingStats {
            mean: stats.mean / 10.0,
            median: stats.median / 10.0,
            stddev: stats.stddev / 10.0,
            min: stats.min / 10.0,
            max: stats.max / 10.0,
            iters: stats.iters,
        },
        iter_flops,
    );

    // Full SAP solve at the reference config and at a tuned-style config.
    for (label, cfg) in [
        ("SAP solve (reference)", SapConfig::reference()),
        (
            "SAP solve (tuned-style)",
            SapConfig {
                algorithm: ranntune::sap::SapAlgorithm::QrLsqr,
                sketch: SketchKind::LessUniform,
                sampling_factor: 4.0,
                vec_nnz: 4,
                safety_factor: 0,
            },
        ),
    ] {
        let stats = time_fn(1, 5, || {
            let mut r = Rng::new(9);
            std::hint::black_box(solve_sap(a, &problem.b, &cfg, &mut r));
        });
        add(label, stats, 0.0);
    }

    // Dense GEMM rate (roofline context for the QR/SVD numbers).
    let g1 = Mat::from_fn(256, 256, |_, _| rng.normal());
    let g2 = Mat::from_fn(256, 256, |_, _| rng.normal());
    add(
        "gemm 256³",
        time_fn(2, 10, || {
            std::hint::black_box(gemm(&g1, &g2));
        }),
        2.0 * 256f64.powi(3),
    );

    // GP fit + EI propose at tuning-loop size (40 samples, 5 dims).
    let xs: Vec<Vec<f64>> = (0..40).map(|_| (0..5).map(|_| rng.uniform()).collect()).collect();
    let ys: Vec<f64> = xs.iter().map(|x| x.iter().sum::<f64>()).collect();
    add(
        "GP fit (40×5) + propose",
        time_fn(0, 3, || {
            let mut r = Rng::new(3);
            let gp = GpModel::fit(&xs, &ys, 3, &mut r);
            std::hint::black_box(ranntune::gp::propose_ei(&gp, 5, 1.0, None, 512, 0, &mut r));
        }),
        0.0,
    );

    let rows: Vec<Vec<String>> = raw
        .iter()
        .map(|(name, med, min, gflops)| {
            vec![
                name.clone(),
                fmt_secs(*med),
                fmt_secs(*min),
                if *gflops > 0.0 { format!("{gflops:.2}") } else { "-".into() },
            ]
        })
        .collect();
    let table = markdown_table(&["path", "median", "min", "GFLOP/s"], &rows);
    println!("{table}");
    let _ = ranntune::bench_harness::write_result(
        &common::results_dir(),
        "hotpath_micro",
        "Hot-path micro benchmarks",
        &["path", "median", "min", "GFLOP/s"],
        &rows,
    );

    // Machine-readable snapshot for the CI perf trajectory (uploaded as a
    // workflow artifact; diffable across commits).
    use ranntune::json::Json;
    let json_rows: Vec<Json> = raw
        .iter()
        .map(|(name, med, min, gflops)| {
            Json::obj(vec![
                ("path", Json::Str(name.clone())),
                ("median_s", Json::Num(*med)),
                ("min_s", Json::Num(*min)),
                ("gflops", Json::Num(*gflops)),
            ])
        })
        .collect();
    let snapshot = Json::obj(vec![
        ("bench", Json::Str("hotpath_micro".into())),
        ("m", Json::Num(m as f64)),
        ("n", Json::Num(n as f64)),
        ("d", Json::Num(d as f64)),
        ("rows", Json::Arr(json_rows)),
    ]);
    let dir = common::results_dir();
    let _ = std::fs::create_dir_all(&dir);
    let _ = std::fs::write(dir.join("BENCH_hotpath_micro.json"), snapshot.to_string_pretty());
}
