//! Micro-benchmarks of the L3 hot paths: sketch-apply (both operators),
//! preconditioner factorizations, LSQR/PGD iterations, the full SAP solve,
//! and GP fit/propose. These are the §Perf before/after numbers in
//! EXPERIMENTS.md.
//!
//! The `cmp:` rows compare the persistent-pool kernels against scoped
//! baselines that re-implement the pre-pool behaviour (a fresh
//! `std::thread::scope` spawn/join per call) at identical flop counts —
//! the delta is exactly the dispatch overhead the pool exists to delete.

mod common;

use ranntune::bench_harness::{fmt_secs, markdown_table, time_fn};
use ranntune::data::{generate_synthetic, SyntheticKind};
use ranntune::gp::GpModel;
use ranntune::linalg::{gemm, gemm_into_unblocked, gemm_packed_into, Mat};
use ranntune::rng::Rng;
use ranntune::sap::{solve_sap, Preconditioner, SapConfig};
use ranntune::sketch::{make_sketch, SketchKind, SketchOp};

/// Dimension override for CI smoke runs: RANNTUNE_BENCH_M / RANNTUNE_BENCH_N
/// shrink the problem below the interactive floor (the CI bench-smoke job
/// runs at a few hundred rows so the whole binary finishes in seconds).
fn env_dim(var: &str, default: usize) -> usize {
    std::env::var(var).ok().and_then(|s| s.parse().ok()).filter(|&v| v > 0).unwrap_or(default)
}

fn main() {
    let scale = common::bench_scale();
    let m = env_dim("RANNTUNE_BENCH_M", scale.m.max(2000));
    let n = env_dim("RANNTUNE_BENCH_N", scale.n.max(64)).min(m);
    let d = 4 * n;
    let mut rng = Rng::new(1);
    println!("== hot-path micro benches (m={m}, n={n}, d={d}) ==\n");

    let problem = generate_synthetic(SyntheticKind::GA, m, n, &mut rng);
    let a = problem.dense();
    // (name, median_s, min_s, gflops) — gflops 0.0 when no flop count
    // applies. The display table is derived from this after the runs.
    let mut raw: Vec<(String, f64, f64, f64)> = Vec::new();
    let mut add = |name: &str, stats: ranntune::bench_harness::TimingStats, flops: f64| {
        let gflops = if flops > 0.0 && stats.median > 0.0 {
            flops / stats.median / 1e9
        } else {
            0.0
        };
        raw.push((name.to_string(), stats.median, stats.min, gflops));
    };

    // Sketch applies: LessUniform (d·k·n flops) vs SJLT (m·k·n flops).
    for (kind, k) in [(SketchKind::LessUniform, 8usize), (SketchKind::Sjlt, 8)] {
        let op = make_sketch(kind, d, m, k, &mut rng);
        let flops = 2.0 * op.nnz() as f64 * n as f64;
        let stats = time_fn(2, 8, || {
            std::hint::black_box(op.apply(a));
        });
        add(&format!("sketch_apply {} k={k}", kind.name()), stats, flops);
    }

    // Preconditioner generation.
    let op = make_sketch(SketchKind::LessUniform, d, m, 8, &mut rng);
    let sketch = op.apply(a);
    let qr_flops = 2.0 * d as f64 * (n * n) as f64;
    add(
        "precond QR (d×n)",
        time_fn(1, 5, || {
            std::hint::black_box(Preconditioner::from_qr(&sketch));
        }),
        qr_flops,
    );
    add(
        "precond SVD (d×n)",
        time_fn(1, 3, || {
            std::hint::black_box(Preconditioner::from_svd(&sketch));
        }),
        qr_flops, // same order; reported as effective QR-equivalent rate
    );

    // One LSQR iteration ≈ one A·v + one Aᵀ·u (4mn flops) + O(n) vector ops.
    let precond = Preconditioner::from_qr(&sketch);
    let z0 = vec![0.0; precond.rank()];
    let iter_flops = 4.0 * (m * n) as f64;
    let stats = time_fn(1, 5, || {
        std::hint::black_box(ranntune::sap::lsqr_preconditioned(
            a,
            problem.b(),
            &precond,
            &z0,
            0.0,
            10,
        ));
    });
    add(
        "LSQR 10 iters (per-iter rate)",
        ranntune::bench_harness::TimingStats {
            mean: stats.mean / 10.0,
            median: stats.median / 10.0,
            stddev: stats.stddev / 10.0,
            min: stats.min / 10.0,
            max: stats.max / 10.0,
            iters: stats.iters,
        },
        iter_flops,
    );

    // Full SAP solve at the reference config and at a tuned-style config.
    for (label, cfg) in [
        ("SAP solve (reference)", SapConfig::reference()),
        (
            "SAP solve (tuned-style)",
            SapConfig {
                algorithm: ranntune::sap::SapAlgorithm::QrLsqr,
                sketch: SketchKind::LessUniform,
                sampling_factor: 4.0,
                vec_nnz: 4,
                safety_factor: 0,
            },
        ),
    ] {
        let stats = time_fn(1, 5, || {
            let mut r = Rng::new(9);
            std::hint::black_box(solve_sap(a, problem.b(), &cfg, &mut r));
        });
        add(label, stats, 0.0);
    }

    // Dense GEMM rate (roofline context for the QR/SVD numbers).
    let g1 = Mat::from_fn(256, 256, |_, _| rng.normal());
    let g2 = Mat::from_fn(256, 256, |_, _| rng.normal());
    add(
        "gemm 256³",
        time_fn(2, 10, || {
            std::hint::black_box(gemm(&g1, &g2));
        }),
        2.0 * 256f64.powi(3),
    );

    // GP fit + EI propose at tuning-loop size (40 samples, 5 dims).
    let xs: Vec<Vec<f64>> = (0..40).map(|_| (0..5).map(|_| rng.uniform()).collect()).collect();
    let ys: Vec<f64> = xs.iter().map(|x| x.iter().sum::<f64>()).collect();
    add(
        "GP fit (40×5) + propose",
        time_fn(0, 3, || {
            let mut r = Rng::new(3);
            let gp = GpModel::fit(&xs, &ys, 3, &mut r);
            std::hint::black_box(ranntune::gp::propose_ei(&gp, 5, 1.0, None, 512, 0, &mut r));
        }),
        0.0,
    );

    // --- pool-vs-scoped comparison ------------------------------------
    let nt = ranntune::linalg::num_threads();

    // Bare dispatch: fan nt trivial tasks out and join.
    add(
        &format!("cmp: dispatch pooled ({nt} tasks)"),
        time_fn(10, 50, || {
            ranntune::linalg::pool().run(nt, &|t| {
                std::hint::black_box(t);
            });
        }),
        0.0,
    );
    add(
        &format!("cmp: dispatch scoped ({nt} tasks)"),
        time_fn(10, 50, || {
            std::thread::scope(|s| {
                for t in 0..nt {
                    s.spawn(move || {
                        std::hint::black_box(t);
                    });
                }
            });
        }),
        0.0,
    );

    // GEMM at roofline scale.
    let gemm_flops = 2.0 * 256f64.powi(3);
    add(
        "cmp: gemm 256³ pooled",
        time_fn(2, 10, || {
            std::hint::black_box(gemm(&g1, &g2));
        }),
        gemm_flops,
    );
    add(
        "cmp: gemm 256³ scoped",
        time_fn(2, 10, || {
            std::hint::black_box(gemm_scoped(&g1, &g2));
        }),
        gemm_flops,
    );

    // Packed BLIS-style GEMM vs the unblocked row-band kernel at the QR
    // trailing-update shape, driven through the always-packed /
    // always-unblocked entry points so the dispatch cutoff cannot blur
    // the comparison (fixed dims so it is stable across smoke
    // overrides). Both rows land in BENCH_kernels.json; CI gates
    // packed ≤ 1.0× unblocked.
    let (pm, pk, pn) = (4096usize, 256usize, 256usize);
    let pa = Mat::from_fn(pm, pk, |_, _| rng.normal());
    let pb = Mat::from_fn(pk, pn, |_, _| rng.normal());
    let mut pc = Mat::zeros(pm, pn);
    let packed_flops = 2.0 * (pm * pk * pn) as f64;
    add(
        "cmp: gemm 4096x256x256 packed",
        time_fn(1, 5, || {
            gemm_packed_into(&pa, &pb, &mut pc);
            std::hint::black_box(&pc);
        }),
        packed_flops,
    );
    let mut pc = Mat::zeros(pm, pn);
    add(
        "cmp: gemm 4096x256x256 unblocked",
        time_fn(1, 5, || {
            gemm_into_unblocked(&pa, &pb, &mut pc);
            std::hint::black_box(&pc);
        }),
        packed_flops,
    );

    // SIMD vs scalar at the dispatched hot paths: the packed GEMM
    // microkernel, the FWHT butterflies, and the column-reduction GEMV.
    // Both variants run in one process via the force-scalar override;
    // the auto row dispatches to whatever the host latched (printed
    // below so the JSON snapshot is interpretable), and on hosts
    // without AVX2/NEON both rows time the same scalar code — CI gates
    // the speedup on x86_64 only and row presence elsewhere.
    {
        use ranntune::linalg::{fwht_pow2, gemv_t, simd_backend, simd_force_scalar};
        println!("simd backend (auto dispatch): {}\n", simd_backend().name());
        let mut sc = Mat::zeros(pm, pn);
        let fw_n = 1usize << 16;
        let fw_src: Vec<f64> = (0..fw_n).map(|_| rng.normal()).collect();
        let mut fw_buf = vec![0.0f64; fw_n];
        // One add + one sub per butterfly pair, n/2 pairs × log2(n) layers.
        let fw_flops = fw_n as f64 * 16.0;
        let (gt_m, gt_n) = (4096usize, 256usize);
        let gt_a = Mat::from_fn(gt_m, gt_n, |_, _| rng.normal());
        let gt_y: Vec<f64> = (0..gt_m).map(|_| rng.normal()).collect();
        let gt_flops = 2.0 * (gt_m * gt_n) as f64;
        for (variant, force) in [("simd", false), ("scalar", true)] {
            simd_force_scalar(force);
            add(
                &format!("cmp: gemm 4096x256x256 {variant}"),
                time_fn(1, 5, || {
                    gemm_packed_into(&pa, &pb, &mut sc);
                    std::hint::black_box(&sc);
                }),
                packed_flops,
            );
            add(
                &format!("cmp: fwht 65536 {variant}"),
                time_fn(5, 20, || {
                    fw_buf.copy_from_slice(&fw_src);
                    fwht_pow2(&mut fw_buf);
                    std::hint::black_box(&fw_buf);
                }),
                fw_flops,
            );
            add(
                &format!("cmp: gemv_t 4096x256 {variant}"),
                time_fn(2, 10, || {
                    std::hint::black_box(gemv_t(&gt_a, &gt_y));
                }),
                gt_flops,
            );
        }
        simd_force_scalar(false);
    }

    // GEMV above the threading cutoff (fixed dims so the comparison is
    // stable across RANNTUNE_BENCH_M/N smoke overrides).
    let gv_a = Mat::from_fn(2048, 1024, |_, _| rng.normal());
    let gv_x: Vec<f64> = (0..1024).map(|_| rng.normal()).collect();
    let gv_flops = 2.0 * (2048 * 1024) as f64;
    add(
        "cmp: gemv 2048×1024 pooled",
        time_fn(2, 10, || {
            std::hint::black_box(ranntune::linalg::gemv(&gv_a, &gv_x));
        }),
        gv_flops,
    );
    add(
        "cmp: gemv 2048×1024 scoped",
        time_fn(2, 10, || {
            std::hint::black_box(gemv_scoped(&gv_a, &gv_x));
        }),
        gv_flops,
    );

    // Blocked compact-WY QR vs the serial unblocked baseline, at the
    // acceptance shape d×n = 4096×256 (fixed dims so the comparison is
    // stable across smoke overrides). Three flavours: the SAP hot path
    // (R + implicit Q, what the preconditioner pays), the same plus an
    // explicit thin Q (what coherence pays), and the seed algorithm
    // (serial rank-1 loop that always materialized Q).
    let (qd, qn) = (4096usize, 256usize);
    let qa = Mat::from_fn(qd, qn, |_, _| rng.normal());
    let qr_fact_flops = 2.0 * qd as f64 * (qn * qn) as f64;
    add(
        "cmp: qr_thin 4096x256 blocked",
        time_fn(1, 3, || {
            std::hint::black_box(ranntune::linalg::qr_thin(&qa));
        }),
        qr_fact_flops,
    );
    add(
        "cmp: qr_thin 4096x256 blocked+thinQ",
        time_fn(1, 3, || {
            std::hint::black_box(ranntune::linalg::qr_thin(&qa).form_thin_q());
        }),
        qr_fact_flops,
    );
    add(
        "cmp: qr_thin 4096x256 unblocked",
        time_fn(1, 3, || {
            std::hint::black_box(ranntune::linalg::qr_thin_unblocked(&qa));
        }),
        qr_fact_flops,
    );

    // Direct least-squares reference solve (the per-problem cost the
    // objective memoizes), blocked implicit-Qᵀb vs the seed path.
    let lstsq_flops = 2.0 * m as f64 * (n * n) as f64;
    add(
        &format!("cmp: lstsq_qr {m}x{n} blocked"),
        time_fn(1, 3, || {
            std::hint::black_box(ranntune::linalg::lstsq_qr(a, problem.b()));
        }),
        lstsq_flops,
    );
    add(
        &format!("cmp: lstsq_qr {m}x{n} unblocked"),
        time_fn(1, 3, || {
            std::hint::black_box(lstsq_unblocked(a, problem.b()));
        }),
        lstsq_flops,
    );

    // Sketch apply at bench scale (SJLT, the band-partitioned operator).
    let cmp_op = make_sketch(SketchKind::Sjlt, d, m, 8, &mut rng);
    let cmp_nz = sketch_rows_nz(cmp_op.as_ref());
    let sk_flops = 2.0 * cmp_op.nnz() as f64 * n as f64;
    add(
        "cmp: sketch_apply SJLT k=8 pooled",
        time_fn(2, 8, || {
            std::hint::black_box(cmp_op.apply(a));
        }),
        sk_flops,
    );
    add(
        "cmp: sketch_apply SJLT k=8 scoped",
        time_fn(2, 8, || {
            std::hint::black_box(sketch_apply_scoped(&cmp_nz, a));
        }),
        sk_flops,
    );

    // --- out-of-core paths: multi-leaf TSQR plus the blockwise sketch
    // apply, streamed vs in-memory at identical flop counts. The tall
    // default (2^20 × 64) runs ~64 leaves under the default block policy;
    // the CI smoke override shrinks it through the same env knobs.
    {
        use ranntune::data::{DenseSource, MatSource};
        let tm = env_dim("RANNTUNE_BENCH_M", 1 << 20);
        let tn = env_dim("RANNTUNE_BENCH_N", 64).min(tm);
        let mut trng = Rng::new(17);
        let ta = Mat::from_fn(tm, tn, |_, _| trng.normal());
        let tb: Vec<f64> = (0..tm).map(|_| trng.normal()).collect();
        let src = DenseSource::new(ta);
        let leaves = tm.div_ceil(src.block_rows().max(tn));
        add(
            &format!("tsqr {tm}x{tn} ({leaves} leaves)"),
            time_fn(1, 3, || {
                std::hint::black_box(ranntune::linalg::tsqr(&src, &tb));
            }),
            2.0 * tm as f64 * (tn * tn) as f64,
        );

        let st_op = make_sketch(SketchKind::Sjlt, d, m, 8, &mut rng);
        let st_src = DenseSource::new(a.clone());
        let st_flops = 2.0 * st_op.nnz() as f64 * n as f64;
        add(
            "cmp: sketch_stream SJLT k=8 in-memory",
            time_fn(2, 8, || {
                std::hint::black_box(st_op.apply(a));
            }),
            st_flops,
        );
        add(
            "cmp: sketch_stream SJLT k=8 blocked",
            time_fn(2, 8, || {
                let mut out = Mat::zeros(d, n);
                st_op.apply_blocks(&st_src, &mut out);
                std::hint::black_box(out);
            }),
            st_flops,
        );
    }

    // --- problem-family objectives at fixed shapes (stable across the
    // smoke overrides): one evaluator repeat at each family's reference
    // configuration, measured end to end. These rows land in
    // BENCH_kernels.json so CI tracks every family's solve cost, not
    // just the sap-ls hot path.
    {
        use ranntune::objective::TimingMode;
        let fp = ranntune::data::build_problem("GA", 1200, 32, 42).expect("dataset");
        for (label, fam_name) in [
            ("family: ridge_solve 1200x32", "ridge"),
            ("family: rand_lowrank 1200x32", "rand-lowrank"),
            ("family: krr_rff 1200x32", "krr-rff"),
        ] {
            let fam = ranntune::families::get(fam_name).expect("registered family");
            let reference = fam.reference(&fp);
            let cfg = fam.ref_config();
            add(
                label,
                time_fn(1, 3, || {
                    let mut r = Rng::new(5);
                    std::hint::black_box(fam.run_repeat(
                        &fp,
                        &reference,
                        &cfg,
                        TimingMode::Measured,
                        &mut r,
                    ));
                }),
                0.0,
            );
        }
    }

    let rows: Vec<Vec<String>> = raw
        .iter()
        .map(|(name, med, min, gflops)| {
            vec![
                name.clone(),
                fmt_secs(*med),
                fmt_secs(*min),
                if *gflops > 0.0 { format!("{gflops:.2}") } else { "-".into() },
            ]
        })
        .collect();
    let table = markdown_table(&["path", "median", "min", "GFLOP/s"], &rows);
    println!("{table}");
    let _ = ranntune::bench_harness::write_result(
        &common::results_dir(),
        "hotpath_micro",
        "Hot-path micro benchmarks",
        &["path", "median", "min", "GFLOP/s"],
        &rows,
    );

    // Machine-readable snapshot for the CI perf trajectory (uploaded as a
    // workflow artifact; diffable across commits).
    use ranntune::json::Json;
    let json_rows: Vec<Json> = raw
        .iter()
        .map(|(name, med, min, gflops)| {
            Json::obj(vec![
                ("path", Json::Str(name.clone())),
                ("median_s", Json::Num(*med)),
                ("min_s", Json::Num(*min)),
                ("gflops", Json::Num(*gflops)),
            ])
        })
        .collect();
    let snapshot = Json::obj(vec![
        ("bench", Json::Str("hotpath_micro".into())),
        ("m", Json::Num(m as f64)),
        ("n", Json::Num(n as f64)),
        ("d", Json::Num(d as f64)),
        ("rows", Json::Arr(json_rows)),
    ]);
    let dir = common::results_dir();
    let _ = std::fs::create_dir_all(&dir);
    let _ = std::fs::write(dir.join("BENCH_hotpath_micro.json"), snapshot.to_string_pretty());

    // Kernel-trajectory snapshot: just the deterministic-kernel rows
    // (blocked vs unblocked QR, packed vs unblocked GEMM, simd vs
    // scalar microkernels, lstsq, full SAP solves) that the CI
    // bench-smoke job publishes as BENCH_kernels.json at the repo root
    // and gates against regression.
    let kernel_rows: Vec<Json> = raw
        .iter()
        .filter(|(name, ..)| {
            name.contains("qr_thin")
                || name.contains("lstsq_qr")
                || name.contains("tsqr")
                || name.contains("sketch_stream")
                || name.contains("gemm 4096x256x256")
                || name.contains("fwht")
                || name.contains("gemv_t")
                || name.starts_with("SAP solve")
                || name.starts_with("family:")
        })
        .map(|(name, med, min, gflops)| {
            Json::obj(vec![
                ("path", Json::Str(name.clone())),
                ("median_s", Json::Num(*med)),
                ("min_s", Json::Num(*min)),
                ("gflops", Json::Num(*gflops)),
            ])
        })
        .collect();
    let kernels = Json::obj(vec![
        ("bench", Json::Str("kernels".into())),
        ("m", Json::Num(m as f64)),
        ("n", Json::Num(n as f64)),
        ("d", Json::Num(d as f64)),
        ("rows", Json::Arr(kernel_rows)),
    ]);
    let _ = std::fs::write(dir.join("BENCH_kernels.json"), kernels.to_string_pretty());
}

/// x = R⁻¹Qᵀb through the seed QR (explicit thin Q + dense Qᵀb product) —
/// the pre-blocking `lstsq_qr`, kept as the cmp baseline.
fn lstsq_unblocked(a: &Mat, b: &[f64]) -> Vec<f64> {
    let (q, r) = ranntune::linalg::qr_thin_unblocked(a);
    let qtb = ranntune::linalg::gemv_t(&q, b);
    ranntune::linalg::solve_upper(&r, &qtb)
}

// ---- scoped baselines (the pre-pool kernels, for the `cmp:` rows) ----

/// C = A·B with a fresh `std::thread::scope` per call — the old gemm
/// threading, kept here as the dispatch-overhead baseline.
fn gemm_scoped(a: &Mat, b: &Mat) -> Mat {
    let (m, _k) = a.shape();
    let n = b.cols();
    let mut c = Mat::zeros(m, n);
    let nt = ranntune::linalg::num_threads().min(m.max(1));
    let rows_per = m.div_ceil(nt);
    let bands: Vec<(usize, &mut [f64])> =
        c.as_mut_slice().chunks_mut(rows_per * n).enumerate().collect();
    std::thread::scope(|s| {
        for (t, band) in bands {
            let lo = t * rows_per;
            s.spawn(move || {
                let hi = lo + band.len() / n;
                gemm_rows_scoped(a, b, band, lo, hi);
            });
        }
    });
    c
}

fn gemm_rows_scoped(a: &Mat, b: &Mat, c_band: &mut [f64], row_lo: usize, row_hi: usize) {
    let k = a.cols();
    let n = b.cols();
    const KB: usize = 256;
    for kb in (0..k).step_by(KB) {
        let kmax = (kb + KB).min(k);
        for i in row_lo..row_hi {
            let arow = a.row(i);
            let crow = &mut c_band[(i - row_lo) * n..(i - row_lo + 1) * n];
            for kk in kb..kmax {
                let aik = arow[kk];
                if aik == 0.0 {
                    continue;
                }
                for (cj, bj) in crow.iter_mut().zip(b.row(kk).iter()) {
                    *cj += aik * bj;
                }
            }
        }
    }
}

/// y = A·x with a fresh `std::thread::scope` per call.
fn gemv_scoped(a: &Mat, x: &[f64]) -> Vec<f64> {
    let m = a.rows();
    let mut y = vec![0.0; m];
    let nt = ranntune::linalg::num_threads();
    let rows_per = m.div_ceil(nt);
    let chunks: Vec<&mut [f64]> = y.chunks_mut(rows_per).collect();
    std::thread::scope(|s| {
        for (t, band) in chunks.into_iter().enumerate() {
            let lo = t * rows_per;
            s.spawn(move || {
                for (r, yo) in band.iter_mut().enumerate() {
                    *yo = ranntune::linalg::dot(a.row(lo + r), x);
                }
            });
        }
    });
    y
}

/// Recover the per-output-row non-zeros of a sketching operator from its
/// dense form, so the scoped baseline applies the *same* sparse gather at
/// the same flop count as the library's threaded apply.
fn sketch_rows_nz(op: &dyn SketchOp) -> Vec<Vec<(usize, f64)>> {
    let dense = op.to_dense();
    (0..op.d())
        .map(|r| {
            (0..op.m())
                .filter_map(|j| {
                    let v = dense[(r, j)];
                    (v != 0.0).then_some((j, v))
                })
                .collect()
        })
        .collect()
}

/// Â = S·A as a row-banded gather with a fresh `std::thread::scope` per
/// call — the pre-pool sketch-apply threading shape.
fn sketch_apply_scoped(rows_nz: &[Vec<(usize, f64)>], a: &Mat) -> Mat {
    let d = rows_nz.len();
    let n = a.cols();
    let mut out = Mat::zeros(d, n);
    let nt = ranntune::linalg::num_threads().min(d.max(1));
    let rows_per = d.div_ceil(nt);
    let bands: Vec<(usize, &mut [f64])> =
        out.as_mut_slice().chunks_mut(rows_per * n).enumerate().collect();
    std::thread::scope(|s| {
        for (t, band) in bands {
            let lo = t * rows_per;
            s.spawn(move || {
                for (rr, orow) in band.chunks_mut(n).enumerate() {
                    for &(j, v) in &rows_nz[lo + rr] {
                        ranntune::linalg::axpy(v, a.row(j), orow);
                    }
                }
            });
        }
    });
    out
}
