//! Bench: regenerate Figures 6 & 7 (TLA source / bandit-constant ablations).
mod common;

fn main() {
    let scale = common::bench_scale();
    println!("== Figure 6 (scale: {}) ==", scale.label);
    println!("{}", ranntune::cli::figures::fig6(&scale, &common::results_dir()));
    println!("== Figure 7 ==");
    println!("{}", ranntune::cli::figures::fig7(&scale, &common::results_dir()));
}
