//! Bench: AOT (JAX+Pallas via PJRT) solve latency vs the native Rust
//! solver vs the direct QR solver — the deployment-path numbers.

mod common;

use ranntune::bench_harness::{fmt_secs, markdown_table, time_fn};
use ranntune::data::{generate_synthetic, SyntheticKind};
use ranntune::linalg::lstsq_qr;
use ranntune::rng::Rng;
use ranntune::runtime::{default_artifacts_dir, SapEngine};
use ranntune::sap::{solve_sap, SapAlgorithm, SapConfig};
use ranntune::sketch::{LessUniform, SketchKind};

fn main() {
    let engine = match SapEngine::load(&default_artifacts_dir(), "sap_small") {
        Ok(e) => e,
        Err(e) => {
            println!("SKIP aot_runtime bench: {e:#}");
            return;
        }
    };
    let meta = engine.meta.clone();
    let (m, n) = (meta.m - 124, meta.n - 28);
    let mut rng = Rng::new(5);
    let problem = generate_synthetic(SyntheticKind::GA, m, n, &mut rng);
    let op = LessUniform::sample(meta.d, m, meta.k, &mut rng);
    let plan = op.row_plan(meta.k).unwrap();
    println!("== AOT runtime bench (m={m}, n={n}, artifact {}) ==\n", meta.name);

    let mut rows = Vec::new();

    let stats = time_fn(2, 8, || {
        std::hint::black_box(engine.solve(problem.dense(), problem.b(), &plan).unwrap());
    });
    rows.push(vec![
        "AOT PJRT (fixed 30 iters, f32)".into(),
        fmt_secs(stats.median),
        fmt_secs(stats.min),
    ]);

    let cfg = SapConfig {
        algorithm: SapAlgorithm::QrLsqr,
        sketch: SketchKind::LessUniform,
        sampling_factor: meta.d as f64 / n as f64,
        vec_nnz: meta.k,
        safety_factor: 0,
    };
    let stats = time_fn(2, 8, || {
        let mut r = Rng::new(9);
        std::hint::black_box(solve_sap(problem.dense(), problem.b(), &cfg, &mut r));
    });
    rows.push(vec![
        "native Rust SAP (adaptive, f64)".into(),
        fmt_secs(stats.median),
        fmt_secs(stats.min),
    ]);

    let stats = time_fn(1, 5, || {
        std::hint::black_box(lstsq_qr(problem.dense(), problem.b()));
    });
    rows.push(vec!["direct QR (f64)".into(), fmt_secs(stats.median), fmt_secs(stats.min)]);

    let table = markdown_table(&["solver", "median", "min"], &rows);
    println!("{table}");
    let _ = ranntune::bench_harness::write_result(
        &common::results_dir(),
        "aot_runtime",
        "AOT vs native vs direct solve latency",
        &["solver", "median", "min"],
        &rows,
    );
}
