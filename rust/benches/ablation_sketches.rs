//! Ablation: sketching-operator families — the paper's §3.2 claim that
//! "an SRHT-based approach would not improve upon sparse sketching
//! operators". Compares apply cost, preconditioner quality (LSQR
//! iterations), and end-to-end solve time for LessUniform, SJLT, SRHT,
//! and dense Gaussian at equal sketch size d = 4n.

mod common;

use ranntune::bench_harness::{fmt_secs, markdown_table, time_fn};
use ranntune::data::{generate_synthetic, SyntheticKind};
use ranntune::linalg::Mat;
use ranntune::rng::Rng;
use ranntune::sap::{lsqr_preconditioned, Preconditioner};
use ranntune::sketch::{GaussianSketch, LessUniform, SketchOp, Sjlt, Srht};

fn main() {
    let scale = common::bench_scale();
    let (m, n) = (scale.m.max(2000), scale.n.max(64));
    let d = 4 * n;
    let mut rng = Rng::new(11);
    let problem = generate_synthetic(SyntheticKind::T3, m, n, &mut rng);
    let a: &Mat = problem.dense();
    println!("== sketch-operator ablation (T3, m={m}, n={n}, d={d}) ==\n");

    let ops: Vec<(&str, Box<dyn SketchOp>)> = vec![
        ("LessUniform k=8", Box::new(LessUniform::sample(d, m, 8, &mut rng))),
        ("SJLT k=8", Box::new(Sjlt::sample(d, m, 8, &mut rng))),
        ("SRHT", Box::new(Srht::sample(d, m, &mut rng))),
        ("Gaussian", Box::new(GaussianSketch::sample(d, m, &mut rng))),
    ];

    let mut rows = Vec::new();
    for (name, op) in &ops {
        let apply_stats = time_fn(1, 5, || {
            std::hint::black_box(op.apply(a));
        });
        let sketch = op.apply(a);
        let p = Preconditioner::from_qr(&sketch);
        let z0 = vec![0.0; p.rank()];
        let res = lsqr_preconditioned(a, problem.b(), &p, &z0, 1e-8, 400);
        let total_stats = time_fn(1, 3, || {
            let sk = op.apply(a);
            let p = Preconditioner::from_qr(&sk);
            let z0 = vec![0.0; p.rank()];
            std::hint::black_box(lsqr_preconditioned(a, problem.b(), &p, &z0, 1e-8, 400));
        });
        rows.push(vec![
            name.to_string(),
            format!("{}", op.nnz()),
            fmt_secs(apply_stats.median),
            format!("{}{}", res.iterations, if res.converged { "" } else { " (limit)" }),
            fmt_secs(total_stats.median),
        ]);
    }
    let headers = ["operator", "nnz", "S·A apply", "LSQR iters (1e-8)", "sketch+QR+LSQR"];
    println!("{}", markdown_table(&headers, &rows));
    let _ = ranntune::bench_harness::write_result(
        &common::results_dir(),
        "ablation_sketches",
        "Sketching-operator ablation (§3.2: sparse vs SRHT vs Gaussian)",
        &headers,
        &rows,
    );
}
