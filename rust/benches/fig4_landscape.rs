//! Bench: regenerate Figure 4 (grid landscape on GA/T5/T3/T1).
mod common;

fn main() {
    let scale = common::bench_scale();
    println!("== Figure 4 (scale: {}) ==", scale.label);
    let report = ranntune::cli::figures::grid_figure(
        &scale,
        &["GA", "T5", "T3", "T1"],
        "fig4",
        &common::results_dir(),
    );
    println!("{report}");
}
