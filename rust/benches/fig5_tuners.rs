//! Bench: regenerate Figure 5 (tuner comparison on synthetic matrices).
mod common;

fn main() {
    let scale = common::bench_scale();
    println!("== Figure 5 (scale: {}) ==", scale.label);
    let report = ranntune::cli::figures::tuner_figure(
        &scale,
        &["GA", "T5", "T3", "T1"],
        "fig5",
        &common::results_dir(),
    );
    println!("{report}");
}
