//! Bench: regenerate Figure 1 (SAP performance vs sketching matrix).
mod common;

fn main() {
    let scale = common::bench_scale();
    println!("== Figure 1 (scale: {}) ==", scale.label);
    let report = ranntune::cli::figures::fig1(&scale, &common::results_dir());
    println!("{report}");
}
