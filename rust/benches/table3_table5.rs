//! Bench: regenerate Table 3 (matrix properties) and Table 5 (Sobol
//! sensitivity).
mod common;

fn main() {
    let scale = common::bench_scale();
    let out = common::results_dir();
    println!("== Table 3 (scale: {}) ==", scale.label);
    println!("{}", ranntune::cli::figures::table3(&scale, &out));
    println!("== Table 5 ==");
    println!("{}", ranntune::cli::figures::table5(&scale, &out));
}
