//! Shared bench scaffolding: scale selection via RANNTUNE_SCALE
//! (small | default | paper; benches default to small so `cargo bench`
//! finishes in minutes).

// Compiled once per bench binary; not every bench uses every helper.
#![allow(dead_code)]

use ranntune::cli::figures::FigScale;

pub fn bench_scale() -> FigScale {
    match std::env::var("RANNTUNE_SCALE").as_deref() {
        Ok("paper") => FigScale::paper(),
        Ok("default") => FigScale::default_(),
        _ => FigScale::small(),
    }
}

pub fn results_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(
        std::env::var("RANNTUNE_RESULTS").unwrap_or_else(|_| "results".into()),
    )
}
